"""The resident co-search service.

A `SearchService` is one long-lived process answering many (workload,
constraint-box) questions. It is built on three observations about the
engine layer:

  1. **Everything expensive is reusable.** Jit caches are process-global;
     `FactorizedSpace` factor tables and `SlabBoundEvaluator`
     dyadic-interval tables key on frozen dataclasses
     (`core.factorized.cached_bound_evaluator`); candidate launches are
     pow2-shape-bucketed. A standing service pays each of these once.
  2. **Answers are canonical.** Every engine x (shard, chunk_size)
     combination returns byte-identical winners/frontiers, so a memo
     keyed on the canonicalized (workload fingerprint, constraint box,
     space, objective) — `serve.cache` — can return the stored result
     object for any respelling of the same question.
  3. **Tightened boxes are incremental.** A bound-guided search that kept
     its `SlabLedger` has already priced every slab it pruned. Under a
     tightened box C' of the original box B, constraint-pruned slabs stay
     dead (their lower bound beat B's limit, and C' only lowers limits)
     and the evaluated region's feasible-under-C' points are exactly the
     stored points inside C'. Only objective-pruned slabs whose stored
     lower bounds *straddle* the new incumbent/frontier can hide a better
     answer — the service re-prices the ledger in one vectorized compare,
     seeds the BnB driver with the best stored points (`WarmStart`), and
     descends only the revived slabs. The result is byte-identical to a
     cold `search()` under C' because the stored bounds are admissible
     and the seeds are true achievable values.

Queries run synchronously: `query()` answers one question,
`submit()`/`drain()` queue several and coalesce the cold ones into
multi-workload batched calls (`serve.batching`).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import logging
import time
from typing import Dict, List, Mapping, Optional, Union

import numpy as np

from repro.core.arch_params import Constraints
from repro.core.factorized import (FactorizedSpace, SlabLedger,
                                   factorized_evaluate_grid)
from repro.core.photonic_model import CONSTANTS, DeviceConstants
from repro.core.runtime import (QueryTimeout, RuntimePolicy, SearchRuntime,
                                fingerprint, query_policy)
from repro.core.search import (DEFAULT_OBJECTIVES, ParetoResult,
                               SearchResult, WarmStart,
                               _bnb_dominated_vs, _bnb_infeasible_mask,
                               _check_pareto_metrics, _measure_band,
                               _pareto_factorized_bnb, _pareto_from_rows,
                               _resolve_robust, _search_factorized_bnb,
                               search, search_workloads)
from repro.core.workload import Workload

from .batching import QueryBatcher, ServeQuery
from .cache import (Box, base_key, box_constraints, box_contains,
                    canonical_box, query_key, workload_key)

log = logging.getLogger("repro.serve")

Result = Union[SearchResult, ParetoResult]


@dataclasses.dataclass
class _BaseEntry:
    """The box-independent warm-start substrate of one (workload,
    objective) pair: the cold search's slab ledger plus the float64
    reference metrics of every point it evaluated. Any later box inside
    `box` is answerable by re-pricing this entry."""

    box: Box                         # the box the ledger was priced under
    ledger: SlabLedger
    idx: np.ndarray                  # (E,) flat indices of evaluated points
    rows: np.ndarray                 # (E, 5) their decoded config rows
    met: Dict[str, np.ndarray]       # {metric: (E,) float64} reference vals
    nbytes: int = 0                  # ledger npz size (the LRU budget unit)


class SearchService:
    """Persistent DSE server: memoized, batched, warm-started searches.

    Construction fixes the *space side* of every query — the factorized
    product space, the engine, device constants, sharding/streaming shape
    and the Pallas interpret flag — because those are what the resident
    caches key on. The *question side* (workload, constraint box,
    objective) arrives per query.

    Args:
      space: candidate sets of the product space (anything
        `FactorizedSpace.from_space` accepts); defaults to the full
        `1..n_z` space.
      n_z: per-axis candidate count of the default space.
      engine: numpy | jax | pallas — all byte-identical; the engine only
        decides where evaluation runs.
      interpret: Pallas interpret mode (CPU); pass False on a real TPU.
      shard / chunk_size: forwarded to every search (see `search`).
      checkpoint_root: when set, every cold search runs under a
        `core.runtime` policy checkpointing into a service-owned
        per-query-fingerprint directory (`runtime.query_checkpoint_dir`),
        so a restarted service resumes in-flight queries. A query that
        actually resumed returns no ledger, so it seeds no warm-start
        entry — correctness never depends on the checkpoint history.
      c: device constants of the photonic model.
      calibration: a `core.calibration.CalibratedConstants` (or a
        `{field: interval}` mapping, or a preset name) — the service's
        calibration uncertainty. Mutually exclusive with a non-default
        `c=`. Without `robust=`, searches run at `calibration.nominal()`;
        every answer carries its uncertainty band on ``result.band``.
      robust: "worst_case" makes the whole service robust: every cold
        search, warm constraint-delta, and memoized answer is priced at
        the calibration's certified worst corner (see `core.search` —
        the warm ledger re-pricing stays sound because the stored bounds
        were built at the same corner the deltas re-price at).
        Calibrations with uncertified varying fields are rejected here:
        the service's warm path needs the worst-corner reduction.
      max_bases / max_ledger_bytes: bound the resident warm-start memory
        — the number of `_BaseEntry` substrates and their total ledger
        byte size (each accounted at its exact `SlabLedger.nbytes()` npz
        round-trip). When either budget is exceeded the least recently
        *used* base entries are evicted (`stats["evicted_bases"]`); an
        evicted base only downgrades its successors from warm to cold —
        answers never change, because the memo of exact results is
        separate and every cold search is self-contained.
      workers / deterministic: fan every cold search's slab queue out
        across the leased parallel scheduler
        (`repro.parallel.slab_sched`), and run warm constraint-deltas
        through the same worker fan-out. Answers stay byte-identical
        (deterministic mode) or exactly-verified-identical (async) to a
        single-executor service, per `core.search.search(workers=)`.

    The constants fingerprint (`constants_fingerprint`) joins every memo
    / base key and therefore the per-query checkpoint directories —
    services over different constants, calibrations, or robust modes
    never share answers, ledgers, or snapshots.

    Every returned result is byte-identical (winners/frontiers) to the
    equivalent cold `core.search.search` call; only wall-time and
    delta-work counters differ on warm paths. `stats` counts how each
    query was served (memo / warm / cold / batched).
    """

    def __init__(self, *, space=None, n_z: int = 12, engine: str = "jax",
                 interpret: bool = True, shard: Optional[int] = None,
                 chunk_size: Optional[int] = None,
                 checkpoint_root: Optional[str] = None,
                 c: DeviceConstants = CONSTANTS,
                 calibration=None, robust: Optional[str] = None,
                 max_bases: Optional[int] = None,
                 max_ledger_bytes: Optional[int] = None,
                 workers: Optional[int] = None,
                 deterministic: bool = True):
        self.space = (FactorizedSpace.full(n_z) if space is None
                      else FactorizedSpace.from_space(space))
        self.engine = engine
        self.interpret = interpret
        self.shard = shard
        self.chunk_size = chunk_size
        self.checkpoint_root = checkpoint_root
        c, cal, fallback = _resolve_robust(calibration, robust, c, engine)
        if fallback:
            raise ValueError(
                "this calibration has uncertified varying fields "
                f"({cal.unresolved()}): SearchService's warm-start path "
                "requires the certified worst-corner reduction — certify "
                "the field directions (core.calibration.MONOTONE)")
        self.c = c
        self.calibration = cal
        self.robust = robust
        if max_bases is not None and max_bases < 0:
            raise ValueError("max_bases= must be >= 0")
        if max_ledger_bytes is not None and max_ledger_bytes < 0:
            raise ValueError("max_ledger_bytes= must be >= 0")
        self.max_bases = max_bases
        self.max_ledger_bytes = max_ledger_bytes
        self.workers = workers
        self.deterministic = deterministic
        self._memo: Dict[str, Result] = {}
        self._base: "collections.OrderedDict[str, _BaseEntry]" = \
            collections.OrderedDict()
        self._base_bytes = 0
        self._queue = QueryBatcher()
        self.stats = {"queries": 0, "memo_hits": 0, "warm": 0, "cold": 0,
                      "batched_calls": 0, "slabs_repriced": 0,
                      "slabs_revived": 0, "evicted_bases": 0,
                      "timeouts": 0}
        # Frozen-dataclass reprs are deterministic and carry every field,
        # so this digest changes whenever the priced cost model does —
        # including the exact constants corner `robust=` resolved to.
        self._cfp = fingerprint(c=repr(self.c),
                                calibration=repr(self.calibration),
                                robust=self.robust or "")

    @property
    def constants_fingerprint(self) -> str:
        """Digest of the cost model this service prices — the resolved
        `DeviceConstants` (post calibration/robust resolution) plus the
        calibration and robust mode. Joins every memo/base key and the
        per-query checkpoint directories."""
        return self._cfp

    # -- public surface ----------------------------------------------------

    def query(self, wl: Workload,
              constraints: Union[Constraints, Mapping] = Constraints(), *,
              objective: str = "edp",
              pareto_metrics: Optional[tuple] = None) -> Result:
        """Answer one question, via memo, warm delta, or cold search.

        Identical questions return the *identical* result object (memo
        hit). A question whose box tightens a previously answered one is
        served by re-pricing that answer's slab ledger (warm). Everything
        else is a cold bound-guided `search` that seeds the memo and the
        warm-start substrate for its successors.
        """
        q = ServeQuery(wl=wl, constraints=box_constraints(
            canonical_box(constraints)), objective=objective,
            pareto_metrics=pareto_metrics)
        self.stats["queries"] += 1
        res = self._serve_memo_or_warm(q)
        if res is None:
            res = self._serve_cold_one(q)
        return res

    def submit(self, wl: Workload,
               constraints: Union[Constraints, Mapping] = Constraints(), *,
               objective: str = "edp",
               pareto_metrics: Optional[tuple] = None,
               deadline_s: Optional[float] = None) -> None:
        """Queue a question for the next `drain()` (FIFO).

        `deadline_s` gives the query a wall-clock budget: a cold search
        that outlives it is cancelled cooperatively (at a unit/merge
        boundary — the in-flight wave unwinds cleanly, worker pools and
        checkpoints included) and surfaces as a typed
        `core.runtime.QueryTimeout` in that query's `drain()` slot
        instead of hanging the batch. Memo/warm answers ignore the
        deadline (they cost microseconds), and deadline queries are
        never coalesced into a shared batched launch.
        """
        if deadline_s is not None and deadline_s < 0:
            raise ValueError("deadline_s= must be >= 0")
        self._queue.put(ServeQuery(wl=wl, constraints=box_constraints(
            canonical_box(constraints)), objective=objective,
            pareto_metrics=pareto_metrics, deadline_s=deadline_s))

    def drain(self) -> List[Union[Result, QueryTimeout]]:
        """Answer every queued question, in arrival order.

        Memo hits and warm deltas are peeled off individually (they cost
        microseconds); the remaining cold queries are coalesced by
        `QueryBatcher.group` into as few multi-workload
        `search_workloads` calls as their (objective, metrics, name)
        signatures allow — on the pallas engine without `prune`, such a
        call is literally one fused launch; under the bound-guided driver
        it still shares every resident table and jit cache.

        A query submitted with `deadline_s=` that exceeds its budget
        returns the raised `QueryTimeout` (carrying ``query_name``) in
        its slot — the rest of the batch completes normally, so the
        caller gets every completed result plus the timed-out names.
        """
        queries = self._queue.take()
        out: Dict[int, Union[Result, QueryTimeout]] = {}
        cold: List[tuple] = []  # (position, query)
        seen: Dict[str, int] = {}  # mkey -> first cold position
        for pos, q in enumerate(queries):
            self.stats["queries"] += 1
            res = self._serve_memo_or_warm(q)
            if res is not None:
                out[pos] = res
                continue
            if q.deadline_s is not None:
                # Deadline queries run their own cancellable campaign
                # immediately — a shared wave has no per-member abort.
                try:
                    out[pos] = self._serve_cold_one(q)
                except QueryTimeout as e:
                    self.stats["timeouts"] += 1
                    out[pos] = e
                continue
            mkey = self._keys(q)[1]
            if mkey in seen:  # duplicate within this drain: one search
                self.stats["memo_hits"] += 1
            else:
                seen[mkey] = pos
                cold.append((pos, q))
        if self.checkpoint_root is not None:
            # Checkpointed colds run one campaign per query fingerprint;
            # batching would fold them into per-name directories instead.
            for pos, q in cold:
                out[pos] = self._serve_cold_one(q)
        else:
            for sig, wave in QueryBatcher.group([q for _, q in cold]):
                self._serve_cold_wave(sig, wave)
                self.stats["batched_calls"] += 1
        for pos, q in enumerate(queries):
            if pos not in out:
                out[pos] = self._memo[self._keys(q)[1]]
        return [out[i] for i in range(len(queries))]

    @staticmethod
    def timed_out(results) -> List[str]:
        """The timed-out query names in a `drain()` return value."""
        return [r.query_name for r in results
                if isinstance(r, QueryTimeout)]

    def stats_delta(self, before: Mapping[str, int]) -> Dict[str, int]:
        """Counter increments since a ``dict(service.stats)`` snapshot —
        how a span of queries (e.g. one `repro.scenarios.sweep`) was
        served, independent of the service's earlier history."""
        return {k: v - int(before.get(k, 0)) for k, v in self.stats.items()}

    # -- internals ---------------------------------------------------------

    def _metrics(self, q: ServeQuery) -> Optional[tuple]:
        if q.objective != "pareto":
            return None
        return _check_pareto_metrics(self.engine,
                                     q.pareto_metrics or DEFAULT_OBJECTIVES)

    def _keys(self, q: ServeQuery):
        wkey = workload_key(q.wl)
        metrics = self._metrics(q)
        return (wkey,
                query_key(wkey, q.box, self.space.axes, q.objective,
                          metrics, constants=self._cfp),
                base_key(wkey, self.space.axes, q.objective, metrics,
                         constants=self._cfp))

    def _serve_memo_or_warm(self, q: ServeQuery) -> Optional[Result]:
        _, mkey, bkey = self._keys(q)
        if mkey in self._memo:
            self.stats["memo_hits"] += 1
            return self._memo[mkey]
        base = self._base.get(bkey)
        if base is not None and box_contains(base.box, q.box):
            self._base.move_to_end(bkey)  # LRU touch: this base just served
            res = self._delta(base, q)
            self.stats["warm"] += 1
            self._memo[mkey] = res
            return res
        return None

    def _cold_kwargs(self, mkey: str) -> dict:
        kw = dict(engine=self.engine, c=self.c, interpret=self.interpret,
                  objective="edp", shard=self.shard,
                  chunk_size=self.chunk_size, factorized=True,
                  space=self.space, prune="bound", keep_ledger=True)
        if self.workers is not None:
            kw["workers"] = self.workers
            kw["deterministic"] = self.deterministic
        if self.checkpoint_root is not None:
            kw["runtime"] = query_policy(self.checkpoint_root, mkey)
        return kw

    def _serve_cold_one(self, q: ServeQuery) -> Result:
        _, mkey, bkey = self._keys(q)
        kw = self._cold_kwargs(mkey)
        kw["objective"] = q.objective
        if q.objective == "pareto":
            kw["pareto_metrics"] = self._metrics(q)
        if q.deadline_s is not None:
            pol = kw.pop("runtime", None)
            pol = (dataclasses.replace(pol, deadline_s=q.deadline_s)
                   if pol is not None
                   else RuntimePolicy(deadline_s=q.deadline_s))
            rt = SearchRuntime(pol)
            rt.query_name = q.wl.name
            kw["runtime"] = rt
        res = search(q.wl, q.constraints, **kw)
        self._finish_cold(q, bkey, mkey, res)
        return res

    def _serve_cold_wave(self, sig, wave: List[ServeQuery]) -> None:
        objective, metrics = sig
        kw = self._cold_kwargs("")
        kw.pop("runtime", None)
        kw["objective"] = objective
        if objective == "pareto":
            # The wave signature carries the metrics as *submitted*; a
            # None (defaulted) tuple still needs the same normalization
            # `query()` applies, or the batched call would crash where
            # the one-at-a-time path succeeds.
            kw["pareto_metrics"] = metrics or self._metrics(wave[0])
        wls = {q.wl.name: q.wl for q in wave}
        cons = {q.wl.name: q.constraints for q in wave}
        results = search_workloads(wls, cons, **kw)
        for q in wave:
            _, mkey, bkey = self._keys(q)
            self._finish_cold(q, bkey, mkey, results[q.wl.name])

    def _finish_cold(self, q: ServeQuery, bkey: str, mkey: str,
                     res: Result) -> None:
        self.stats["cold"] += 1
        if self.calibration is not None:
            res.band = _measure_band(res, self.calibration, q.wl)
        self._memo[mkey] = res
        ledger = res.ledger
        if ledger is None:
            return  # resumed-from-checkpoint run: no complete partition
        prior = self._base.get(bkey)
        if prior is not None and not box_contains(q.box, prior.box):
            # The standing entry covers boxes this one would not; keep it.
            return
        idx = ledger.evaluated_indices()
        met = factorized_evaluate_grid(self.space, q.wl, self.c, idx=idx)
        prior = self._base.pop(bkey, None)
        if prior is not None:
            self._base_bytes -= prior.nbytes
        entry = _BaseEntry(
            box=q.box, ledger=ledger, idx=idx,
            rows=self.space.decode(idx),
            met={k: np.asarray(v, np.float64) for k, v in met.items()},
            nbytes=ledger.nbytes())
        self._base[bkey] = entry
        self._base_bytes += entry.nbytes
        self._evict_bases()

    def _evict_bases(self) -> None:
        """Evict least-recently-used base entries until both budgets hold.

        Eviction is availability, not correctness: a dropped base only
        means the next tightened-box query runs cold (and re-seeds the
        entry) instead of warm — the memo of exact results is untouched.
        """
        while self._base and (
                (self.max_bases is not None
                 and len(self._base) > self.max_bases)
                or (self.max_ledger_bytes is not None
                    and self._base_bytes > self.max_ledger_bytes)):
            bkey, entry = self._base.popitem(last=False)
            self._base_bytes -= entry.nbytes
            self.stats["evicted_bases"] += 1
            log.debug("evicted base %s (%d bytes; %d bases / %d bytes "
                      "resident)", bkey[:12], entry.nbytes,
                      len(self._base), self._base_bytes)

    def _maybe_executor(self, wl, cons, objective, metrics):
        """A leased worker fan-out for one warm delta, or a None context.

        Warm deltas always use the *deterministic* wave fan-out even on
        an async-configured service: the async drivers own their whole
        probe/refine/sweep schedule and have no warm-start entry point,
        and a delta's revived-slab descent is small enough that the
        byte-identical wave split is the right tool anyway.
        """
        if self.workers is None:
            return contextlib.nullcontext(None)
        from repro.parallel.slab_sched import SlabScheduler
        return SlabScheduler(self.space, wl, cons, self.c, self.interpret,
                             self.shard, self.chunk_size, self.workers,
                             objective=objective, objectives=metrics,
                             deterministic=True)

    def _delta(self, base: _BaseEntry, q: ServeQuery) -> Result:
        """Warm constraint-delta answer: filter the point store, re-price
        the pruned slabs, descend only the revived ones."""
        t0 = time.perf_counter()
        cons = q.constraints
        dead = _bnb_infeasible_mask(base.ledger.bounds, cons)
        if q.objective == "edp":
            m = base.met
            ok = np.asarray(cons.satisfied(m["area"], m["power"],
                                           m["energy"], m["latency"]))
            gidx, edp = base.idx[ok], m["edp"][ok]
            if len(gidx):
                k = np.lexsort((gidx, edp))[0]
                best = (int(gidx[k]), float(edp[k]))
                dead |= np.asarray(base.ledger.bounds["edp"]) > best[1]
            else:
                best = (-1, float("inf"))
            warm = WarmStart(
                start=base.ledger.pruned[~dead],
                lbs={k2: v[~dead]
                     for k2, v in base.ledger.bounds.items()},
                best=best, nf=int(ok.sum()))
            with self._maybe_executor(q.wl, cons, "edp", None) as ex:
                res = _search_factorized_bnb(
                    self.space, q.wl, cons, self.engine, self.c,
                    self.interpret, self.shard, self.chunk_size,
                    warm=warm, executor=ex)
        else:
            metrics = self._metrics(q)
            front, met, nf = _pareto_from_rows(base.rows, q.wl, cons,
                                               self.c, metrics, m=base.met)
            pts = (np.stack([met[k] for k in metrics], axis=1)
                   if len(front) else np.zeros((0, len(metrics))))
            dead |= _bnb_dominated_vs(pts, base.ledger.bounds, metrics)
            warm = WarmStart(
                start=base.ledger.pruned[~dead],
                lbs={k2: v[~dead]
                     for k2, v in base.ledger.bounds.items()},
                rows=front, met=met, nf=nf)
            with self._maybe_executor(q.wl, cons, "pareto", metrics) as ex:
                res = _pareto_factorized_bnb(
                    self.space, q.wl, cons, self.engine, self.c,
                    self.interpret, metrics, self.shard, self.chunk_size,
                    warm=warm, executor=ex)
        if self.calibration is not None:
            res.band = _measure_band(res, self.calibration, q.wl)
        self.stats["slabs_repriced"] += len(base.ledger.pruned)
        self.stats["slabs_revived"] += int((~dead).sum())
        log.debug("delta query served warm in %.3fms: %d/%d slabs revived",
                  (time.perf_counter() - t0) * 1e3, int((~dead).sum()),
                  len(base.ledger.pruned))
        return res
