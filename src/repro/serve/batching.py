"""Request queue and batcher for the search service.

Concurrent queries rarely deserve one launch each: the engine layer
already answers W workloads under W different constraint boxes in a
*single* fused multi-workload launch (`core.search.search_workloads`,
whose constraints travel as a dynamic `(W, 4)` operand and whose
candidate shapes are pow2-bucketed so scenario sweeps never recompile).
The batcher's job is to coalesce the queue into as few such calls as
possible without changing any answer:

  * queries already memoized or eligible for the warm constraint-delta
    path are peeled off first (they cost microseconds each — batching
    them would only delay them);
  * the remaining cold queries are grouped by (objective, metric tuple)
    — the only axes `search_workloads` cannot vary within one call —
    and each group becomes one batched call;
  * within a group, workload *names* must be unique (they key the
    batched result dict), so duplicate names are split into successive
    waves rather than renamed — a renamed workload would fingerprint
    differently and poison the memo.

The batcher is synchronous and deterministic: `drain()` processes the
queue in arrival order and returns results in arrival order, which is
what makes the service's batched path testable against the sequential
path byte-for-byte.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.arch_params import Constraints
from repro.core.workload import Workload

from .cache import Box, canonical_box


@dataclasses.dataclass(frozen=True)
class ServeQuery:
    """One queued question: a workload under a constraint box.

    `objective` / `pareto_metrics` follow `core.search.search`;
    `pareto_metrics` is ignored (and excluded from the memo key) in
    "edp" mode. `deadline_s` is a per-query wall-clock budget: a cold
    search past it raises `core.runtime.QueryTimeout` (cooperatively, at
    a unit/merge boundary). Deadline queries are never coalesced into a
    batched wave — a shared launch has no per-member cancellation — so
    the field stays out of the wave signature by construction.
    """

    wl: Workload
    constraints: Constraints
    objective: str = "edp"
    pareto_metrics: Optional[tuple] = None
    deadline_s: Optional[float] = None

    @property
    def box(self) -> Box:
        """The query's canonical constraint box."""
        return canonical_box(self.constraints)


class QueryBatcher:
    """Order-preserving queue that coalesces cold queries into waves.

    `group(queries)` partitions a list of cold queries into *waves*: each
    wave maps one (objective, metrics) group with pairwise-distinct
    workload names onto a single `search_workloads` call. The partition
    is greedy in arrival order, so the first occurrence of every name
    lands in the earliest possible wave and results stay reproducible.
    """

    def __init__(self):
        self._pending: List[ServeQuery] = []

    def put(self, query: ServeQuery) -> None:
        """Enqueue a query (FIFO)."""
        self._pending.append(query)

    def take(self) -> List[ServeQuery]:
        """Drain and return the queue in arrival order."""
        out, self._pending = self._pending, []
        return out

    def __len__(self) -> int:
        return len(self._pending)

    @staticmethod
    def group(queries: List[ServeQuery]
              ) -> List[Tuple[Tuple[str, Optional[tuple]],
                              List[ServeQuery]]]:
        """Partition cold queries into batched-call waves.

        Returns `[((objective, metrics), [queries...]), ...]`: every
        inner list has pairwise-distinct workload names and one
        (objective, metrics) signature, so it maps 1:1 onto a
        `search_workloads(wls={...}, constraints={...})` call.
        """
        waves: List[Tuple[Tuple[str, Optional[tuple]],
                          List[ServeQuery]]] = []
        for q in queries:
            sig = (q.objective,
                   None if q.objective == "edp" else q.pareto_metrics)
            for wave_sig, wave in waves:
                if wave_sig == sig and all(w.wl.name != q.wl.name
                                           for w in wave):
                    wave.append(q)
                    break
            else:
                waves.append((sig, [q]))
        return waves
