"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free token mixing with
data-dependent per-channel decay.

Recurrence (per head, K = key dim, V = value dim):
    out_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
with w_t in (0,1) produced by a LoRA on the token-shifted input.

Two WKV evaluation modes:
  * "scan"    — exact sequential lax.scan over time (baseline; numerically
                robust; tiny HLO; dominates step latency at long seq).
  * "chunked" — GLA-style chunked form: intra-chunk factored decay GEMMs +
                inter-chunk state scan. MXU-friendly; requires bounded
                per-chunk decay (we clamp log w; see EXPERIMENTS §Perf for
                the hillclimb where this path replaces "scan").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import NULL_RULES, shard

from .layers import _normal, init_rmsnorm, matmul32, rms_norm

WKV_MODE = "scan"  # module default; overridden per-call
_LOG_W_MIN = -8.0  # chunked-mode decay clamp (exp(-8)/token floor)


def init_rwkv_time(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    k = d // h
    ks = jax.random.split(key, 8)
    return {
        "mu": _normal(ks[0], (5, d), 0.02),            # r, k, v, g, w shifts
        "wr": _normal(ks[1], (d, d), d ** -0.5),
        "wk": _normal(ks[2], (d, d), d ** -0.5),
        "wv": _normal(ks[3], (d, d), d ** -0.5),
        "wg": _normal(ks[4], (d, d), d ** -0.5),
        "w_base": jnp.full((h, k), -1.0, jnp.float32),  # decay bias
        "w_lora_a": _normal(ks[5], (d, 64), d ** -0.5),
        "w_lora_b": _normal(ks[6], (64, d), 64 ** -0.5),
        "u": jnp.zeros((h, k), jnp.float32),            # current-token bonus
        "ln_out": init_rmsnorm(d),
        "wo": _normal(ks[7], (d, d), d ** -0.5),
    }


def init_rwkv_channel(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu": _normal(ks[0], (2, d), 0.02),            # k, r shifts
        "wk": _normal(ks[1], (d, cfg.d_ff), d ** -0.5),
        "wv": _normal(ks[2], (cfg.d_ff, d), cfg.d_ff ** -0.5),
        "wr": _normal(jax.random.fold_in(key, 9), (d, d), d ** -0.5),
    }


def rwkv_time_specs(rules):
    return {"mu": rules.replicated, "wr": rules.w_col, "wk": rules.w_col,
            "wv": rules.w_col, "wg": rules.w_col, "w_base": rules.replicated,
            "w_lora_a": rules.replicated, "w_lora_b": rules.replicated,
            "u": rules.replicated, "ln_out": {"scale": rules.replicated},
            "wo": rules.w_row}


def rwkv_channel_specs(rules):
    return {"mu": rules.replicated, "wk": rules.w_col, "wv": rules.w_row,
            "wr": rules.w_col}


def _shift(x, last):
    """Token shift: x_{t-1} with `last` (B, 1, D) filling t=0."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _wkv_scan(r, k, v, w, u, s0):
    """Exact recurrence. r/k/w: (B, T, H, K); v: (B, T, H, V).
    Returns (out (B, T, H, V), s_final (B, H, K, V))."""

    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]           # (B, H, K, V)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[..., None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32) for t in (r, k, v, w))
    s_final, out = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(out, 0, 1), s_final


def _wkv_chunked(r, k, v, w, u, s0, chunk=64):
    """GLA-style chunked evaluation (MXU-friendly). Same contract as
    _wkv_scan; requires T % chunk == 0. Decay is clamped for stability."""
    b, t, h, kd = k.shape
    vd = v.shape[-1]
    q = chunk
    n = t // q
    r, k, v = (x.astype(jnp.float32) for x in (r, k, v))
    lw = jnp.clip(jnp.log(w.astype(jnp.float32)), _LOG_W_MIN, 0.0)
    rc = r.reshape(b, n, q, h, kd)
    kc = k.reshape(b, n, q, h, kd)
    vc = v.reshape(b, n, q, h, vd)
    lcum = jnp.cumsum(lw.reshape(b, n, q, h, kd), axis=2)   # incl. own w
    p_t = lcum - lw.reshape(b, n, q, h, kd)                 # sum_{s<t} lw_s

    # Factored intra-chunk attention: coeff(t, tau) = exp(p_t - lcum_tau),
    # valid/used for tau < t. |p_t| bounded by chunk * |LOG_W_MIN|.
    r_dec = rc * jnp.exp(p_t)
    k_dec = kc * jnp.exp(-lcum)
    scores = jnp.einsum("bnqhk,bnthk->bnhqt", r_dec, k_dec)
    mask = jnp.tril(jnp.ones((q, q), bool), k=-1)           # strictly past
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    bonus = jnp.einsum("bnqhk,hk,bnqhk->bnqh", rc, u, kc)   # current token
    y = jnp.einsum("bnhqt,bnthv->bnqhv", scores, vc) \
        + bonus[..., None] * vc

    # Chunk summary: S_chunk = sum_t exp(lcum_end - lcum_t) k_t v_t^T
    kw = kc * jnp.exp(lcum[:, :, -1:, :, :] - lcum)
    s_chunk = jnp.einsum("bnthk,bnthv->bnhkv", kw, vc)
    a_chunk = jnp.exp(lcum[:, :, -1])                       # (B, N, H, K)

    def step(s, inp):
        sc, ac, r_d = inp
        y_inter = jnp.einsum("bqhk,bhkv->bqhv", r_d, s)
        s = ac[..., None] * s + sc
        return s, y_inter

    s_final, y_inter = jax.lax.scan(
        step, s0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(a_chunk, 1, 0),
                   jnp.moveaxis(r_dec, 1, 0)))
    y = y + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(b, t, h, vd), s_final


def _decay(params, xw):
    lora = jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    h, kd = params["w_base"].shape
    wl = params["w_base"] + lora.reshape(*lora.shape[:-1], h, kd)
    return jnp.exp(-jnp.exp(wl.astype(jnp.float32)))        # (B,T,H,K) in (0,1)


def apply_rwkv_time(params, cfg, x, *, last=None, state=None,
                    wkv_mode=None, rules=NULL_RULES):
    """Time-mix over a full sequence (or one step when x is (B, 1, D) and
    state/last are provided). Returns (out, (last_x, state))."""
    b, t, d = x.shape
    h = cfg.n_heads
    kd = d // h
    wkv_mode = wkv_mode or WKV_MODE
    xs = _shift(x, last)
    mu = params["mu"]
    xr, xk, xv, xg, xw = (_lerp(x, xs, mu[i]) for i in range(5))
    r = (xr @ params["wr"]).reshape(b, t, h, kd)
    k = (xk @ params["wk"]).reshape(b, t, h, kd)
    v = (xv @ params["wv"]).reshape(b, t, h, kd)
    g = xg @ params["wg"]
    r = shard(r, rules.heads)
    k = shard(k, rules.heads)
    v = shard(v, rules.heads)
    w = _decay(params, xw)
    if state is None:
        state = jnp.zeros((b, h, kd, kd), jnp.float32)
    if t == 1:
        out, s_new = _wkv_scan(r, k, v, w, params["u"], state)
    elif wkv_mode == "chunked":
        out, s_new = _wkv_chunked(r, k, v, w, params["u"], state)
    else:
        out, s_new = _wkv_scan(r, k, v, w, params["u"], state)
    out = out.reshape(b, t, d).astype(x.dtype)
    out = rms_norm(params["ln_out"], out, cfg.norm_eps)
    out = (out * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype))
    out = matmul32(out, params["wo"]).astype(x.dtype)
    return out, (x[:, -1:], s_new)


def apply_rwkv_channel(params, cfg, x, *, last=None, rules=NULL_RULES):
    """Channel-mix (the RWKV FFN). Returns (out, last_x)."""
    xs = _shift(x, last)
    mu = params["mu"]
    xk = _lerp(x, xs, mu[0])
    xr = _lerp(x, xs, mu[1])
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    k = shard(k, rules.ffn_hidden)
    kv = matmul32(k, params["wv"]).astype(x.dtype)
    return jax.nn.sigmoid((xr @ params["wr"]).astype(jnp.float32)
                          ).astype(x.dtype) * kv, x[:, -1:]
