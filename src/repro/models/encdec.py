"""Encoder-decoder (SeamlessM4T-medium backbone): bidirectional encoder over
stub audio-frame embeddings, causal decoder with cross-attention.

The audio frontend (conformer feature extractor) is a STUB per the
assignment: `input_specs()` supplies precomputed (B, S_src, d_model) frame
embeddings; a learned adapter projection stands in for the modality bridge.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import NULL_RULES, shard

from .layers import (DTYPE, _normal, apply_attention, apply_mlp, einsum32, embed, gqa_attend, init_attention, init_embedding, init_mlp, init_rmsnorm, matmul32, project_kv, rms_norm, softmax_xent, unembed)
from .lm import _decode_positions


def _init_enc_block(key, cfg):
    ks = jax.random.split(key, 2)
    return {"ln1": init_rmsnorm(cfg.d_model),
            "attn": init_attention(ks[0], cfg),
            "ln2": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff)}


def _init_dec_block(key, cfg):
    ks = jax.random.split(key, 3)
    return {"ln1": init_rmsnorm(cfg.d_model),
            "self_attn": init_attention(ks[0], cfg),
            "ln2": init_rmsnorm(cfg.d_model),
            "cross_attn": init_attention(ks[1], cfg),
            "ln3": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff)}


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    return {
        "adapter": _normal(ks[0], (cfg.d_model, cfg.d_model),
                           cfg.d_model ** -0.5),
        "enc_layers": jax.vmap(lambda k: _init_enc_block(k, cfg))(
            jax.random.split(ks[1], cfg.enc_layers)),
        "enc_norm": init_rmsnorm(cfg.d_model),
        "embed": init_embedding(ks[2], cfg.vocab, cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _init_dec_block(k, cfg))(
            jax.random.split(ks[3], cfg.dec_layers)),
        "final_norm": init_rmsnorm(cfg.d_model),
        "head": init_embedding(ks[4], cfg.vocab, cfg.d_model),
    }


def _cross_attend(p, cfg, x, mem_k, mem_v, rules):
    """Cross-attention: queries from decoder state, K/V precomputed from
    encoder memory (no rope — absolute alignment lives in the encoder)."""
    q = einsum32("bsd,dhk->bshk", x, p["wq"]).astype(x.dtype)
    q = shard(q, rules.heads)
    b, sq = x.shape[:2]
    mask = jnp.ones((b, sq, mem_k.shape[1]), bool)
    out = gqa_attend(q, mem_k, mem_v, mask, cfg.attn_logit_softcap)
    return einsum32("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)


def _cross_kv(p, x):
    k = einsum32("bsd,dhk->bshk", x, p["wk"]).astype(x.dtype)
    v = einsum32("bsd,dhk->bshk", x, p["wv"]).astype(x.dtype)
    return k, v


def encode(params, cfg, src_embeds, rules=NULL_RULES, remat=True):
    x = matmul32(src_embeds.astype(DTYPE), params["adapter"]).astype(DTYPE)
    x = shard(x, rules.resid)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, p):
        h = rms_norm(p["ln1"], carry, cfg.norm_eps)
        carry = carry + apply_attention(p["attn"], cfg, h, positions,
                                        rules=rules, causal=False)
        carry = shard(carry, rules.resid)
        h = rms_norm(p["ln2"], carry, cfg.norm_eps)
        carry = shard(carry + apply_mlp(p["mlp"], h, cfg.act, rules),
                      rules.resid)
        return carry, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return rms_norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(p, cfg, x, positions, mem_k, mem_v, rules, *, self_kv=None,
               kv_positions=None):
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    x = x + apply_attention(p["self_attn"], cfg, h, positions, kv=self_kv,
                            kv_positions=kv_positions, rules=rules)
    x = shard(x, rules.resid)
    h = rms_norm(p["ln2"], x, cfg.norm_eps)
    x = shard(x + _cross_attend(p["cross_attn"], cfg, h, mem_k, mem_v,
                                rules), rules.resid)
    h = rms_norm(p["ln3"], x, cfg.norm_eps)
    return shard(x + apply_mlp(p["mlp"], h, cfg.act, rules), rules.resid)


def forward(params, cfg: ModelConfig, batch, rules=NULL_RULES, remat=True):
    """batch: {"src_embeds": (B, Ss, D), "tokens": (B, St)}."""
    memory = encode(params, cfg, batch["src_embeds"], rules, remat)
    y = embed(params["embed"], batch["tokens"])
    y = shard(y, rules.resid)
    b, st, _ = y.shape
    positions = jnp.broadcast_to(jnp.arange(st), (b, st))

    def body(carry, p):
        mem_k, mem_v = _cross_kv(p["cross_attn"], memory)
        return _dec_block(p, cfg, carry, positions, mem_k, mem_v, rules), None

    fn = jax.checkpoint(body) if remat else body
    y, _ = jax.lax.scan(fn, y, params["dec_layers"])
    y = rms_norm(params["final_norm"], y, cfg.norm_eps)
    logits = shard(unembed(params["head"], y), rules.logits)
    return {"logits": logits, "aux_moe": 0.0, "n_prefix": 0}


def lm_loss(params, cfg, batch, rules=NULL_RULES, remat=True, **_):
    out = forward(params, cfg, batch, rules, remat)
    return softmax_xent(out["logits"][:, :-1], batch["tokens"][:, 1:]), out


def prefill(params, cfg: ModelConfig, batch, rules=NULL_RULES):
    """Encode + score the target prefix; emit self- and cross-KV caches."""
    memory = encode(params, cfg, batch["src_embeds"], rules, remat=False)
    y = embed(params["embed"], batch["tokens"])
    b, st, _ = y.shape
    positions = jnp.broadcast_to(jnp.arange(st), (b, st))

    def body(carry, p):
        mem_k, mem_v = _cross_kv(p["cross_attn"], memory)
        k, v = project_kv(p["self_attn"], cfg, rms_norm(p["ln1"], carry,
                                                        cfg.norm_eps),
                          positions)
        k = shard(k, rules.kv_cache)
        v = shard(v, rules.kv_cache)
        carry = _dec_block(p, cfg, carry, positions, mem_k, mem_v, rules,
                           self_kv=(k, v), kv_positions=positions)
        return carry, (k, v, mem_k, mem_v)

    y, (ks, vs, mks, mvs) = jax.lax.scan(body, y, params["dec_layers"])
    y = rms_norm(params["final_norm"], y, cfg.norm_eps)
    logits = unembed(params["head"], y[:, -1:])[:, 0]
    return logits, {"k": ks, "v": vs, "cross_k": mks, "cross_v": mvs}


def decode_step(params, cfg: ModelConfig, tokens, pos, cache,
                rules=NULL_RULES):
    x = embed(params["embed"], tokens)
    b = x.shape[0]
    max_len = cache["k"].shape[2]
    q_pos, kv_pos = _decode_positions(b, max_len, pos)

    def body(carry, layer):
        p, k_row, v_row, mk, mv = layer
        h = rms_norm(p["ln1"], carry, cfg.norm_eps)
        k1, v1 = project_kv(p["self_attn"], cfg, h, q_pos)
        k_row = jax.lax.dynamic_update_slice(k_row, k1, (0, pos, 0, 0))
        v_row = jax.lax.dynamic_update_slice(v_row, v1, (0, pos, 0, 0))
        k_row = shard(k_row, rules.kv_cache)
        v_row = shard(v_row, rules.kv_cache)
        carry = carry + apply_attention(p["self_attn"], cfg, h, q_pos,
                                        kv=(k_row, v_row),
                                        kv_positions=kv_pos, rules=rules)
        h = rms_norm(p["ln2"], carry, cfg.norm_eps)
        carry = carry + _cross_attend(p["cross_attn"], cfg, h, mk, mv, rules)
        h = rms_norm(p["ln3"], carry, cfg.norm_eps)
        carry = carry + apply_mlp(p["mlp"], h, cfg.act, rules)
        return carry, (k_row, v_row)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["head"], x)[:, 0]
    return logits, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
