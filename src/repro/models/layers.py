"""Common transformer layers: RMSNorm, RoPE, GQA attention (global / sliding
window / softcap / bias), gated MLP, embeddings, losses.

Functional style: every module has `init_*(key, cfg) -> params` (a dict) and
an apply function. Mixed precision: params and activations bf16, norms and
softmax in f32, matmuls accumulate in f32. Sharding is expressed through
`rules` (repro.parallel.sharding.Rules) — pass NULL_RULES on a single device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import NULL_RULES, shard

DTYPE = jnp.bfloat16

# XLA's CPU thunk runtime lacks several bf16 x bf16 -> f32 dot kernels. When
# executing on CPU (smoke tests, examples), enable exec-safe mode: operands
# are cast to f32 (bit-identical accumulation, since bf16 embeds exactly in
# f32). The dry-run leaves this OFF so the lowered HLO is the TPU-intended
# mixed-precision program.
_EXEC_SAFE = False


def set_exec_safe(v: bool) -> None:
    global _EXEC_SAFE
    _EXEC_SAFE = bool(v)


def einsum32(eq, *ops):
    """einsum with f32 accumulation (MXU-native on TPU; exec-safe on CPU)."""
    if _EXEC_SAFE:
        return jnp.einsum(eq, *(o.astype(jnp.float32) for o in ops))
    return jnp.einsum(eq, *ops, preferred_element_type=jnp.float32)


def matmul32(a, b):
    if _EXEC_SAFE:
        return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def _normal(key, shape, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(DTYPE)


def dense(x, w):
    """x @ w with f32 accumulation, output in x.dtype."""
    return matmul32(x, w).astype(x.dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), DTYPE)}


def rms_norm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------

def rope(x, positions, theta=10000.0):
    """x: (B, S, H, D), positions: (B, S) int."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA; global or sliding-window; optional logit softcap / bias)
# --------------------------------------------------------------------------

def init_attention(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "wq": _normal(ks[0], (d, cfg.n_heads, dh), scale),
        "wk": _normal(ks[1], (d, cfg.n_kv_heads, dh), scale),
        "wv": _normal(ks[2], (d, cfg.n_kv_heads, dh), scale),
        "wo": _normal(ks[3], (cfg.n_heads, dh, d), (cfg.n_heads * dh) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, dh), DTYPE)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, dh), DTYPE)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, dh), DTYPE)
    return p


def attention_specs(rules):
    return {"wq": rules.w_qkv, "wk": rules.w_qkv, "wv": rules.w_qkv,
            "wo": rules.w_out, "bq": rules.b_model, "bk": rules.replicated,
            "bv": rules.replicated}


def attn_mask(q_pos, kv_pos, window: int = 0, is_local=None):
    """(B, Sq, Skv) bool. Causal, optionally sliding-window.

    `is_local` may be a traced scalar bool (per-layer flag inside a scan):
    the window constraint is applied via where(), keeping one code path.
    """
    causal = kv_pos[:, None, :] <= q_pos[:, :, None]
    if window <= 0:
        return causal
    in_win = kv_pos[:, None, :] > (q_pos[:, :, None] - window)
    if is_local is None:
        return causal & in_win
    return causal & jnp.where(is_local, in_win, True)


# GQA evaluation mode: "grouped" computes on the (B,S,Hkv,G,D) view (no KV
# duplication, but the 5-D grouped tensors reshard poorly under GSPMD —
# "involuntary full rematerialization" in the backward); "repeat_kv"
# broadcasts K/V to the full head count first (plain MHA einsums, clean
# head sharding, G x more KV activation). See EXPERIMENTS §Perf (H2).
GQA_MODE = "grouped"


def set_gqa_mode(mode: str) -> None:
    global GQA_MODE
    assert mode in ("grouped", "repeat_kv")
    GQA_MODE = mode


def gqa_attend(q, k, v, mask, softcap: float = 0.0):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); mask: (B, Sq, Skv) bool."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if GQA_MODE == "repeat_kv" and g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        g, hkv = 1, hq
    if g == 1:
        scores = einsum32("bqhd,bkhd->bhqk", q, k) * (d ** -0.5)
        if softcap > 0.0:
            scores = softcap * jnp.tanh(scores / softcap)
        scores = jnp.where(mask[:, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = einsum32("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
        return out.astype(v.dtype)
    q = q.reshape(b, sq, hkv, g, d)
    scores = einsum32("bqhgd,bkhd->bhgqk", q, k)
    scores = scores * (d ** -0.5)
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = einsum32("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, d).astype(v.dtype)


def apply_attention(params, cfg, x, positions, *, kv=None, kv_positions=None,
                    is_local=None, rules=NULL_RULES, causal=True):
    """Self-attention over x, or incremental attention against provided kv.

    kv: optional (k, v) tensors (decode path: the full cache); when given,
    `kv_positions` masks out unwritten cache slots.
    """
    q = einsum32("bsd,dhk->bshk", x, params["wq"]).astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"]
    q = rope(q, positions, cfg.rope_theta)
    q = shard(q, rules.heads)
    if kv is None:
        k = einsum32("bsd,dhk->bshk", x, params["wk"]).astype(x.dtype)
        v = einsum32("bsd,dhk->bshk", x, params["wv"]).astype(x.dtype)
        if "bk" in params:
            k = k + params["bk"]
            v = v + params["bv"]
        k = rope(k, positions, cfg.rope_theta)
        kv_spec = getattr(rules, "kv_heads", None) or rules.heads
        k = shard(k, kv_spec)
        v = shard(v, kv_spec)
        kv_positions = positions
    else:
        k, v = kv
    if causal:
        mask = attn_mask(positions, kv_positions, cfg.sliding_window, is_local)
    else:  # encoder: bidirectional over valid positions
        mask = (kv_positions >= 0)[:, None, :] & jnp.ones(
            (x.shape[0], x.shape[1], 1), bool)
    out = gqa_attend(q, k, v, mask, cfg.attn_logit_softcap)
    out = einsum32("bshk,hkd->bsd", out, params["wo"]).astype(x.dtype)
    return out


def project_kv(params, cfg, x, positions):
    """K/V for cache population (prefill) or appending (decode)."""
    k = einsum32("bsd,dhk->bshk", x, params["wk"]).astype(x.dtype)
    v = einsum32("bsd,dhk->bshk", x, params["wv"]).astype(x.dtype)
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    k = rope(k, positions, cfg.rope_theta)
    return k, v


# --------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------------

def init_mlp(key, d, d_ff):
    ks = jax.random.split(key, 3)
    return {"wi": _normal(ks[0], (d, d_ff), d ** -0.5),
            "wg": _normal(ks[1], (d, d_ff), d ** -0.5),
            "wo": _normal(ks[2], (d_ff, d), d_ff ** -0.5)}


def mlp_specs(rules):
    return {"wi": rules.w_col, "wg": rules.w_col, "wo": rules.w_row}


def apply_mlp(params, x, act="silu", rules=NULL_RULES):
    h = dense(x, params["wi"])
    g = dense(x, params["wg"])
    h = shard(h, rules.ffn_hidden)
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return dense(h * a, params["wo"])


# --------------------------------------------------------------------------
# Embedding + LM head + loss
# --------------------------------------------------------------------------

def init_embedding(key, vocab, d):
    return {"table": _normal(key, (vocab, d), 0.02)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    """(B, S, D) -> logits (B, S, V) against the (possibly tied) table."""
    return einsum32("bsd,vd->bsv", x, params["table"])


# Gold-logit extraction: "gather" (take_along_axis — all-gathers the full
# vocab-sharded logits under GSPMD) vs "onehot" (masked local sum + psum —
# vocab-sharding friendly). See EXPERIMENTS §Perf (H2).
XENT_MODE = "gather"


def set_xent_mode(mode: str) -> None:
    global XENT_MODE
    assert mode in ("gather", "onehot")
    XENT_MODE = mode


def softmax_xent(logits, targets, mask=None):
    """Mean next-token cross-entropy; logits f32 (B, S, V)."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    if XENT_MODE == "onehot":
        vocab_ids = jnp.arange(logits.shape[-1])
        gold = jnp.sum(jnp.where(vocab_ids == targets[..., None], logits,
                                 0.0), axis=-1)
    else:
        gold = jnp.take_along_axis(logits, targets[..., None],
                                   axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
