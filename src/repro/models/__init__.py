"""Model zoo facade: dispatch on cfg.family to the LM or enc-dec assembly."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import encdec, lm
from .layers import DTYPE


def init_params(key, cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.init_params(key, cfg)
    return lm.init_params(key, cfg)


def forward(params, cfg, batch, rules=None, remat=True):
    from repro.parallel.sharding import NULL_RULES
    rules = rules or NULL_RULES
    fn = encdec.forward if cfg.family == "encdec" else lm.forward
    return fn(params, cfg, batch, rules, remat)


def lm_loss(params, cfg, batch, rules=None, remat=True, **kw):
    from repro.parallel.sharding import NULL_RULES
    rules = rules or NULL_RULES
    fn = encdec.lm_loss if cfg.family == "encdec" else lm.lm_loss
    return fn(params, cfg, batch, rules, remat, **kw)


def prefill(params, cfg, batch, rules=None):
    from repro.parallel.sharding import NULL_RULES
    rules = rules or NULL_RULES
    fn = encdec.prefill if cfg.family == "encdec" else lm.prefill
    return fn(params, cfg, batch, rules)


def decode_step(params, cfg, tokens, pos, cache, rules=None):
    from repro.parallel.sharding import NULL_RULES
    rules = rules or NULL_RULES
    fn = encdec.decode_step if cfg.family == "encdec" else lm.decode_step
    return fn(params, cfg, tokens, pos, cache, rules)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int = 0):
    if cfg.family == "encdec":
        dh = cfg.resolved_head_dim
        return {
            "k": jnp.zeros((cfg.dec_layers, batch, max_len, cfg.n_kv_heads,
                            dh), DTYPE),
            "v": jnp.zeros((cfg.dec_layers, batch, max_len, cfg.n_kv_heads,
                            dh), DTYPE),
            "cross_k": jnp.zeros((cfg.dec_layers, batch, src_len,
                                  cfg.n_kv_heads, dh), DTYPE),
            "cross_v": jnp.zeros((cfg.dec_layers, batch, src_len,
                                  cfg.n_kv_heads, dh), DTYPE),
        }
    return lm.init_cache(cfg, batch, max_len)


__all__ = ["init_params", "forward", "lm_loss", "prefill", "decode_step",
           "init_cache"]
