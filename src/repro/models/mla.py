"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill: expand the compressed KV latent to per-head K/V and run
standard attention. Decode: the *absorbed* form — W_UK folds into the query
and W_UV into the output, so attention runs directly against the cached
(B, S, kv_lora_rank) latent + (B, S, rope_dim) shared rope key. The decode KV
cache is rank-compressed (the whole point of MLA) and sequence-shardable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import NULL_RULES, shard

from .layers import _normal, attn_mask, einsum32, init_rmsnorm, matmul32, rms_norm, rope


def init_mla(key, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    qd = m.nope_head_dim + m.rope_head_dim
    p = {
        "wkv_a": _normal(ks[0], (d, m.kv_lora_rank + m.rope_head_dim),
                         d ** -0.5),
        "kv_norm": init_rmsnorm(m.kv_lora_rank),
        "wk_b": _normal(ks[1], (m.kv_lora_rank, h, m.nope_head_dim),
                        m.kv_lora_rank ** -0.5),
        "wv_b": _normal(ks[2], (m.kv_lora_rank, h, m.v_head_dim),
                        m.kv_lora_rank ** -0.5),
        "wo": _normal(ks[3], (h, m.v_head_dim, d), (h * m.v_head_dim) ** -0.5),
    }
    if m.q_lora_rank:
        p["wq_a"] = _normal(ks[4], (d, m.q_lora_rank), d ** -0.5)
        p["q_norm"] = init_rmsnorm(m.q_lora_rank)
        p["wq_b"] = _normal(ks[5], (m.q_lora_rank, h, qd),
                            m.q_lora_rank ** -0.5)
    else:
        p["wq"] = _normal(ks[6], (d, h, qd), d ** -0.5)
    return p


def mla_specs(cfg, rules):
    return {"wkv_a": rules.w_col, "kv_norm": {"scale": rules.replicated},
            "wk_b": rules.w_qkv, "wv_b": rules.w_qkv, "wo": rules.w_out,
            "wq_a": rules.w_col, "q_norm": {"scale": rules.replicated},
            "wq_b": rules.w_qkv, "wq": rules.w_qkv}


def _queries(params, cfg, x, positions, rules):
    m = cfg.mla
    if m.q_lora_rank:
        ql = rms_norm(params["q_norm"],
                      matmul32(x, params["wq_a"]).astype(x.dtype), cfg.norm_eps)
        q = einsum32("bsr,rhk->bshk", ql, params["wq_b"]).astype(x.dtype)
    else:
        q = einsum32("bsd,dhk->bshk", x, params["wq"]).astype(x.dtype)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return shard(q_nope, rules.heads), shard(q_rope, rules.heads)


def latent_kv(params, cfg, x, positions):
    """(c_kv (B, S, R) normalized latent, k_rope (B, S, rope_dim))."""
    m = cfg.mla
    kv_a = matmul32(x, params["wkv_a"]).astype(x.dtype)
    c_kv = rms_norm(params["kv_norm"], kv_a[..., :m.kv_lora_rank],
                    cfg.norm_eps)
    k_rope = rope(kv_a[..., None, m.kv_lora_rank:], positions,
                  cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def apply_mla(params, cfg, x, positions, rules=NULL_RULES):
    """Train/prefill full-sequence MLA (expanded form)."""
    m = cfg.mla
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    q_nope, q_rope = _queries(params, cfg, x, positions, rules)
    c_kv, k_rope = latent_kv(params, cfg, x, positions)
    k_nope = einsum32("bsr,rhk->bshk", c_kv, params["wk_b"]).astype(x.dtype)
    v = einsum32("bsr,rhk->bshk", c_kv, params["wv_b"]).astype(x.dtype)
    k_nope = shard(k_nope, rules.heads)
    v = shard(v, rules.heads)
    mask = attn_mask(positions, positions)
    scores = (einsum32("bqhn,bkhn->bhqk", q_nope, k_nope)
              + einsum32("bqhr,bkr->bhqk", q_rope, k_rope)) * scale
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    ctx = einsum32("bhqk,bkhd->bqhd", probs, v).astype(v.dtype)
    return einsum32("bqhd,hdm->bqm", ctx, params["wo"]).astype(x.dtype)


def decode_mla(params, cfg, x, positions, cache_c, cache_rope, kv_positions,
               rules=NULL_RULES):
    """Absorbed-form decode against the rank-compressed cache.

    cache_c: (B, Smax, R); cache_rope: (B, Smax, rope_dim); x: (B, 1, D).
    """
    m = cfg.mla
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    q_nope, q_rope = _queries(params, cfg, x, positions, rules)
    # Absorb W_UK: query in latent space.
    q_c = einsum32("bqhn,rhn->bqhr", q_nope, params["wk_b"]).astype(x.dtype)
    scores = (einsum32("bqhr,bkr->bhqk", q_c, cache_c)
              + einsum32("bqhr,bkr->bhqk", q_rope, cache_rope)) * scale
    mask = attn_mask(positions, kv_positions)
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_c = einsum32("bhqk,bkr->bqhr", probs, cache_c).astype(x.dtype)
    # Absorb W_UV on the way out.
    ctx = einsum32("bqhr,rhd->bqhd", ctx_c, params["wv_b"]).astype(x.dtype)
    return einsum32("bqhd,hdm->bqm", ctx, params["wo"]).astype(x.dtype)
