"""Decoder-LM assembly for all assigned families.

Layer stacks are *stacked pytrees* scanned with `lax.scan` (small HLO ->
fast 512-way SPMD compiles) and wrapped in `jax.checkpoint` for training
(only the sequence-sharded residual carry is saved). Heterogeneity:

  * gemma3 5:1 SWA        — per-layer `is_local` flag threaded through scan
  * deepseek dense-first   — two stacks (dense FFN, then MoE) scanned in turn
  * zamba2 shared attention — scan over macro-groups: one shared transformer
    block application + `attn_every` Mamba2 layers per group (+ tail stack)
  * rwkv                   — time-mix/channel-mix stacks with shift state

Public API: init_params, forward (train), prefill, decode_step, init_cache.
Cache layouts are stacked over layers so decode is also a layer scan.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import NULL_RULES, shard

from . import mla as mla_mod
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import ssd as ssd_mod
from .layers import (DTYPE, apply_attention, apply_mlp, embed,
                     init_attention, init_embedding, init_mlp, init_rmsnorm,
                     project_kv, rms_norm, softmax_xent, unembed)


# --------------------------------------------------------------------------
# Single transformer block (dense families + zamba shared block + deepseek)
# --------------------------------------------------------------------------

def _init_block(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    p = {"ln1": init_rmsnorm(cfg.d_model), "ln2": init_rmsnorm(cfg.d_model)}
    if kind in ("attn", "mla"):
        p["attn"] = (mla_mod.init_mla(ks[0], cfg) if kind == "mla"
                     else init_attention(ks[0], cfg))
    if kind == "mla":
        pass
    p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    return p


def _init_moe_block(key, cfg, mla: bool):
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model), "ln2": init_rmsnorm(cfg.d_model),
        "attn": (mla_mod.init_mla(ks[0], cfg) if mla
                 else init_attention(ks[0], cfg)),
        "moe": moe_mod.init_moe(ks[1], cfg),
    }


def _moe_groups(rules):
    return getattr(rules, "moe_groups", 1) or 1


def _attn_or_mla(params, cfg, x, positions, *, is_local, rules, mla):
    if mla:
        return mla_mod.apply_mla(params, cfg, x, positions, rules)
    return apply_attention(params, cfg, x, positions, is_local=is_local,
                           rules=rules)


def _block_fwd(params, cfg, x, positions, *, is_local=None, rules=NULL_RULES,
               mla=False, moe=False):
    h = rms_norm(params["ln1"], x, cfg.norm_eps)
    x = x + _attn_or_mla(params["attn"], cfg, h, positions,
                         is_local=is_local, rules=rules, mla=mla)
    x = shard(x, rules.resid)
    h = rms_norm(params["ln2"], x, cfg.norm_eps)
    if moe:
        y, aux = moe_mod.apply_moe_dispatch(params["moe"], cfg, h, rules,
                                         groups=_moe_groups(rules))
    else:
        y, aux = apply_mlp(params["mlp"], h, cfg.act, rules), 0.0
    x = shard(x + y, rules.resid)
    return x, aux


# --------------------------------------------------------------------------
# init_params
# --------------------------------------------------------------------------

def _stacked(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab, cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = init_embedding(ks[1], cfg.vocab, cfg.d_model)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = _stacked(
            ks[2], cfg.n_layers, lambda k: _init_block(k, cfg, "attn"))
    elif fam == "moe":
        params["layers"] = _stacked(
            ks[2], cfg.n_layers, lambda k: _init_moe_block(k, cfg, False))
    elif fam == "mla_moe":
        nd = cfg.moe.first_dense_layers
        params["dense_layers"] = _stacked(
            ks[2], nd, lambda k: _init_block(k, cfg, "mla"))
        params["moe_layers"] = _stacked(
            ks[3], cfg.n_layers - nd, lambda k: _init_moe_block(k, cfg, True))
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": jax.random.normal(ks[4], (2 * cfg.d_model,
                                                  cfg.d_model),
                                          jnp.float32).astype(DTYPE)
                * cfg.d_model ** -0.5,
                "block": _init_block(ks[5], cfg, "mla"),
                "norm_h": init_rmsnorm(cfg.d_model),
                "norm_e": init_rmsnorm(cfg.d_model),
            }
    elif fam == "hybrid_ssm":
        a = cfg.ssm.attn_every
        g = cfg.n_layers // a
        tail = cfg.n_layers - g * a

        def init_mamba_layer(k):
            return {"ln": init_rmsnorm(cfg.d_model),
                    "m": ssd_mod.init_mamba(k, cfg)}

        grouped = _stacked(ks[2], g * a, init_mamba_layer)
        params["mamba_groups"] = jax.tree.map(
            lambda t: t.reshape(g, a, *t.shape[1:]), grouped)
        if tail:
            params["mamba_tail"] = _stacked(ks[3], tail, init_mamba_layer)
        params["shared_attn"] = _init_block(ks[4], cfg, "attn")
    elif fam == "rwkv":
        params["layers"] = _stacked(
            ks[2], cfg.n_layers,
            lambda k: {"ln1": init_rmsnorm(cfg.d_model),
                       "time": rwkv_mod.init_rwkv_time(k, cfg),
                       "ln2": init_rmsnorm(cfg.d_model),
                       "channel": rwkv_mod.init_rwkv_channel(
                           jax.random.fold_in(k, 1), cfg)})
    else:
        raise ValueError(f"init_params: family {fam} handled in encdec.py")
    return params


def swa_flags(cfg) -> Optional[jnp.ndarray]:
    """(L,) bool: True where the layer uses the sliding window."""
    if cfg.sliding_window <= 0:
        return None
    if cfg.swa_pattern <= 0:
        return jnp.ones((cfg.n_layers,), bool)
    idx = jnp.arange(cfg.n_layers)
    return (idx + 1) % cfg.swa_pattern != 0


# --------------------------------------------------------------------------
# Forward (training / scoring)
# --------------------------------------------------------------------------

def _embed_inputs(params, cfg, batch):
    """tokens (+ stub modality embeddings) -> (x, positions, n_prefix)."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens)
    n_prefix = 0
    if cfg.n_prefix_embeds and "embeds" in batch:
        x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
        n_prefix = batch["embeds"].shape[1]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, positions, n_prefix


def _scan_layers(stack, body, x, xs_extra=None, remat=True):
    fn = jax.checkpoint(body) if remat else body
    xs = stack if xs_extra is None else (stack, xs_extra)
    x, _ = jax.lax.scan(fn, x, xs)
    return x


def forward(params, cfg: ModelConfig, batch, rules=NULL_RULES, remat=True):
    """Full-sequence forward. Returns dict(logits, aux_moe, n_prefix,
    mtp_logits?)."""
    x, positions, n_prefix = _embed_inputs(params, cfg, batch)
    x = shard(x, rules.resid)
    fam = cfg.family
    aux_total = 0.0

    if fam in ("dense", "vlm"):
        flags = swa_flags(cfg)

        def body(carry, layer):
            if flags is None:
                p, fl = layer, None
            else:
                p, fl = layer
            h, _ = _block_fwd(p, cfg, carry, positions, is_local=fl,
                              rules=rules)
            return h, 0.0

        x = _scan_layers(params["layers"], body, x,
                         xs_extra=flags, remat=remat)

    elif fam == "moe":
        def body(carry, p):
            h, aux = _block_fwd(p, cfg, carry, positions, rules=rules,
                                moe=True)
            return h, aux

        fn = jax.checkpoint(body) if remat else body
        x, auxs = jax.lax.scan(fn, x, params["layers"])
        aux_total = jnp.sum(auxs)

    elif fam == "mla_moe":
        def dense_body(carry, p):
            h, _ = _block_fwd(p, cfg, carry, positions, rules=rules, mla=True)
            return h, 0.0

        def moe_body(carry, p):
            h, aux = _block_fwd(p, cfg, carry, positions, rules=rules,
                                mla=True, moe=True)
            return h, aux

        x = _scan_layers(params["dense_layers"], dense_body, x, remat=remat)
        fn = jax.checkpoint(moe_body) if remat else moe_body
        x, auxs = jax.lax.scan(fn, x, params["moe_layers"])
        aux_total = jnp.sum(auxs)

    elif fam == "hybrid_ssm":
        def mamba_body(c, p):
            y = ssd_mod.apply_mamba(
                p["m"], cfg, rms_norm(p["ln"], c, cfg.norm_eps), rules=rules)
            return shard(c + y, rules.resid), 0.0

        def group_body(carry, gparams):
            h, _ = _block_fwd(params["shared_attn"], cfg, carry, positions,
                              rules=rules)
            h = _scan_layers(gparams, mamba_body, h, remat=False)
            return h, 0.0

        fn = jax.checkpoint(group_body) if remat else group_body
        x, _ = jax.lax.scan(fn, x, params["mamba_groups"])
        if "mamba_tail" in params:
            x = _scan_layers(params["mamba_tail"], mamba_body, x, remat=remat)

    elif fam == "rwkv":
        def body(carry, p):
            h = rms_norm(p["ln1"], carry, cfg.norm_eps)
            y, _ = rwkv_mod.apply_rwkv_time(p["time"], cfg, h, rules=rules)
            carry = shard(carry + y, rules.resid)
            h = rms_norm(p["ln2"], carry, cfg.norm_eps)
            y, _ = rwkv_mod.apply_rwkv_channel(p["channel"], cfg, h,
                                               rules=rules)
            return shard(carry + y, rules.resid), 0.0

        x = _scan_layers(params["layers"], body, x, remat=remat)
    else:
        raise ValueError(fam)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = shard(unembed(table, x), rules.logits)
    out = {"logits": logits, "aux_moe": aux_total, "n_prefix": n_prefix}

    if cfg.family == "mla_moe" and cfg.mtp_depth and "mtp" in params:
        # DeepSeek-V3 MTP: one extra depth — combine the trunk state with the
        # embedding of the *next* token and predict token t+2.
        mtp = params["mtp"]
        emb_next = jnp.roll(embed(params["embed"], batch["tokens"]), -1,
                            axis=1)
        h = jnp.concatenate([rms_norm(mtp["norm_h"], x, cfg.norm_eps),
                             rms_norm(mtp["norm_e"], emb_next, cfg.norm_eps)],
                            axis=-1) @ mtp["proj"]
        h, _ = _block_fwd(mtp["block"], cfg, h.astype(x.dtype), positions,
                          rules=rules, mla=True)
        out["mtp_logits"] = shard(unembed(table, h), rules.logits)
    return out


def lm_loss(params, cfg, batch, rules=NULL_RULES, remat=True,
            aux_coeff=0.01, mtp_coeff=0.3):
    """Next-token loss (+ MoE aux + MTP)."""
    out = forward(params, cfg, batch, rules, remat)
    logits = out["logits"]
    tokens = batch["tokens"]
    npre = out["n_prefix"]
    # predict tokens[:, 1:] from positions [npre : -1]
    pred = logits[:, npre:-1]
    tgt = tokens[:, 1:]
    loss = softmax_xent(pred, tgt, batch.get("loss_mask"))
    if "mtp_logits" in out:
        pred2 = out["mtp_logits"][:, npre:-2]
        loss = loss + mtp_coeff * softmax_xent(pred2, tokens[:, 2:])
    loss = loss + aux_coeff * out["aux_moe"]
    return loss, out


# --------------------------------------------------------------------------
# KV cache: prefill + decode (layer-stacked, scanned)
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Zeroed cache pytree (shapes only matter for the dry-run)."""
    fam = cfg.family
    dh = cfg.resolved_head_dim
    if fam in ("dense", "vlm"):
        return {"k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                                dh), DTYPE),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                                dh), DTYPE)}
    if fam == "moe":
        return {"k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                                dh), DTYPE),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                                dh), DTYPE)}
    if fam == "mla_moe":
        m = cfg.mla
        return {"c": jnp.zeros((cfg.n_layers, batch, max_len,
                                m.kv_lora_rank), DTYPE),
                "rope": jnp.zeros((cfg.n_layers, batch, max_len,
                                   m.rope_head_dim), DTYPE)}
    if fam == "hybrid_ssm":
        s = cfg.ssm
        g = cfg.n_layers // s.attn_every
        tail = cfg.n_layers - g * s.attn_every
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        conv_dim = d_in + 2 * s.d_state
        cache = {
            "h": jnp.zeros((g * s.attn_every, batch, nh, s.d_state,
                            s.head_dim), jnp.float32),
            "conv": jnp.zeros((g * s.attn_every, batch, s.d_conv - 1,
                               conv_dim), DTYPE),
            "k": jnp.zeros((g, batch, max_len, cfg.n_kv_heads, dh), DTYPE),
            "v": jnp.zeros((g, batch, max_len, cfg.n_kv_heads, dh), DTYPE),
        }
        if tail:
            cache["h_tail"] = jnp.zeros((tail, batch, nh, s.d_state,
                                         s.head_dim), jnp.float32)
            cache["conv_tail"] = jnp.zeros((tail, batch, s.d_conv - 1,
                                            conv_dim), DTYPE)
        return cache
    if fam == "rwkv":
        kd = cfg.d_model // cfg.n_heads
        return {"s": jnp.zeros((cfg.n_layers, batch, cfg.n_heads, kd, kd),
                               jnp.float32),
                "last_t": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                                    DTYPE),
                "last_c": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                                    DTYPE)}
    raise ValueError(fam)


def _decode_positions(batch_size, max_len, pos):
    q_pos = jnp.full((batch_size, 1), pos, jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(max_len, dtype=jnp.int32),
                              (batch_size, max_len))
    return q_pos, kv_pos


def _attn_decode(p, cfg, x, pos, k_row, v_row, is_local, rules):
    """One decode step of a GQA attention block against its cache row."""
    b = x.shape[0]
    max_len = k_row.shape[1]
    q_pos, kv_pos = _decode_positions(b, max_len, pos)
    k1, v1 = project_kv(p, cfg, x, q_pos)
    k_row = jax.lax.dynamic_update_slice(k_row, k1, (0, pos, 0, 0))
    v_row = jax.lax.dynamic_update_slice(v_row, v1, (0, pos, 0, 0))
    k_row = shard(k_row, rules.kv_cache)
    v_row = shard(v_row, rules.kv_cache)
    out = apply_attention(p, cfg, x, q_pos, kv=(k_row, v_row),
                          kv_positions=kv_pos, is_local=is_local, rules=rules)
    return out, k_row, v_row


def _block_decode(p, cfg, x, pos, k_row, v_row, *, is_local=None,
                  rules=NULL_RULES, moe=False):
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    a, k_row, v_row = _attn_decode(p["attn"], cfg, h, pos, k_row, v_row,
                                   is_local, rules)
    x = x + a
    h = rms_norm(p["ln2"], x, cfg.norm_eps)
    if moe:
        y, _ = moe_mod.apply_moe_dispatch(p["moe"], cfg, h, rules,
                                       groups=_moe_groups(rules))
    else:
        y = apply_mlp(p["mlp"], h, cfg.act, rules)
    return x + y, k_row, v_row


def _mla_block_decode(p, cfg, x, pos, c_row, r_row, *, rules=NULL_RULES,
                      moe=False):
    b = x.shape[0]
    max_len = c_row.shape[1]
    q_pos, kv_pos = _decode_positions(b, max_len, pos)
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    c1, r1 = mla_mod.latent_kv(p["attn"], cfg, h, q_pos)
    c_row = jax.lax.dynamic_update_slice(c_row, c1, (0, pos, 0))
    r_row = jax.lax.dynamic_update_slice(r_row, r1, (0, pos, 0))
    a = mla_mod.decode_mla(p["attn"], cfg, h, q_pos, c_row, r_row, kv_pos,
                           rules)
    x = x + a
    h = rms_norm(p["ln2"], x, cfg.norm_eps)
    if moe:
        y, _ = moe_mod.apply_moe_dispatch(p["moe"], cfg, h, rules,
                                       groups=_moe_groups(rules))
    else:
        y = apply_mlp(p["mlp"], h, cfg.act, rules)
    return x + y, c_row, r_row


# --------------------------------------------------------------------------
# Prefill: full-sequence forward that also emits the cache
# --------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch, rules=NULL_RULES):
    """Returns (last-position logits (B, V), cache)."""
    x, positions, _ = _embed_inputs(params, cfg, batch)
    x = shard(x, rules.resid)
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        flags = swa_flags(cfg)
        moe = fam == "moe"

        def body(carry, layer):
            if flags is None:
                p, fl = layer, None
            else:
                p, fl = layer
            h = rms_norm(p["ln1"], carry, cfg.norm_eps)
            k, v = project_kv(p["attn"], cfg, h, positions)
            k = shard(k, rules.kv_cache)
            v = shard(v, rules.kv_cache)
            a = apply_attention(p["attn"], cfg, h, positions, kv=(k, v),
                                kv_positions=positions, is_local=fl,
                                rules=rules)
            carry = carry + a
            h = rms_norm(p["ln2"], carry, cfg.norm_eps)
            if moe:
                y, _ = moe_mod.apply_moe_dispatch(p["moe"], cfg, h, rules,
                                       groups=_moe_groups(rules))
            else:
                y = apply_mlp(p["mlp"], h, cfg.act, rules)
            return shard(carry + y, rules.resid), (k, v)

        xs = params["layers"] if flags is None else (params["layers"], flags)
        x, (ks, vs) = jax.lax.scan(body, x, xs)
        cache = {"k": ks, "v": vs}

    elif fam == "mla_moe":
        def body_factory(moe):
            def body(carry, p):
                h = rms_norm(p["ln1"], carry, cfg.norm_eps)
                c, r = mla_mod.latent_kv(p["attn"], cfg, h, positions)
                a = mla_mod.apply_mla(p["attn"], cfg, h, positions, rules)
                carry = carry + a
                h = rms_norm(p["ln2"], carry, cfg.norm_eps)
                if moe:
                    y, _ = moe_mod.apply_moe_dispatch(p["moe"], cfg, h, rules,
                                       groups=_moe_groups(rules))
                else:
                    y = apply_mlp(p["mlp"], h, cfg.act, rules)
                return shard(carry + y, rules.resid), (c, r)
            return body

        x, (c1, r1) = jax.lax.scan(body_factory(False), x,
                                   params["dense_layers"])
        x, (c2, r2) = jax.lax.scan(body_factory(True), x,
                                   params["moe_layers"])
        cache = {"c": jnp.concatenate([c1, c2]),
                 "rope": jnp.concatenate([r1, r2])}

    elif fam == "hybrid_ssm":
        def mamba_body(c, p):
            y, st = ssd_mod.apply_mamba(
                p["m"], cfg, rms_norm(p["ln"], c, cfg.norm_eps), rules=rules,
                return_state=True)
            return shard(c + y, rules.resid), st

        def group_body(carry, gparams):
            p = params["shared_attn"]
            h = rms_norm(p["ln1"], carry, cfg.norm_eps)
            k, v = project_kv(p["attn"], cfg, h, positions)
            a = apply_attention(p["attn"], cfg, h, positions, kv=(k, v),
                                kv_positions=positions, rules=rules)
            carry = carry + a
            h = rms_norm(p["ln2"], carry, cfg.norm_eps)
            carry = shard(carry + apply_mlp(p["mlp"], h, cfg.act, rules),
                          rules.resid)
            carry, sts = jax.lax.scan(mamba_body, carry, gparams)
            return carry, (sts, k, v)

        x, (sts, ks, vs) = jax.lax.scan(group_body, x,
                                        params["mamba_groups"])
        g, a = sts["h"].shape[:2]
        cache = {"h": sts["h"].reshape(g * a, *sts["h"].shape[2:]),
                 "conv": sts["conv"].reshape(g * a, *sts["conv"].shape[2:]),
                 "k": ks, "v": vs}
        if "mamba_tail" in params:
            x, tail_sts = jax.lax.scan(mamba_body, x, params["mamba_tail"])
            cache["h_tail"] = tail_sts["h"]
            cache["conv_tail"] = tail_sts["conv"]

    elif fam == "rwkv":
        def body(carry, p):
            h = rms_norm(p["ln1"], carry, cfg.norm_eps)
            y, (last_t, s) = rwkv_mod.apply_rwkv_time(p["time"], cfg, h,
                                                      rules=rules)
            carry = shard(carry + y, rules.resid)
            h = rms_norm(p["ln2"], carry, cfg.norm_eps)
            y, last_c = rwkv_mod.apply_rwkv_channel(p["channel"], cfg, h,
                                                    rules=rules)
            return shard(carry + y, rules.resid), (s, last_t, last_c)

        x, (s, last_t, last_c) = jax.lax.scan(body, x, params["layers"])
        cache = {"s": s, "last_t": last_t, "last_c": last_c}
    else:
        raise ValueError(fam)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(table, x[:, -1:])[:, 0]
    return logits, cache


# --------------------------------------------------------------------------
# Decode: one token for the whole stack
# --------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, tokens, pos, cache,
                rules=NULL_RULES):
    """tokens: (B, 1) int32; pos: scalar int32 (current write index).
    Returns (logits (B, V), new_cache)."""
    x = embed(params["embed"], tokens)
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        flags = swa_flags(cfg)
        moe = fam == "moe"

        def body(carry, layer):
            if flags is None:
                (p, k_row, v_row), fl = layer, None
            else:
                p, k_row, v_row, fl = layer
            carry, k_row, v_row = _block_decode(p, cfg, carry, pos, k_row,
                                                v_row, is_local=fl,
                                                rules=rules, moe=moe)
            return carry, (k_row, v_row)

        xs = ((params["layers"], cache["k"], cache["v"]) if flags is None
              else (params["layers"], cache["k"], cache["v"], flags))
        x, (ks, vs) = jax.lax.scan(body, x, xs)
        cache = {"k": ks, "v": vs}

    elif fam == "mla_moe":
        nd = cfg.moe.first_dense_layers

        def body_factory(moe):
            def body(carry, layer):
                p, c_row, r_row = layer
                carry, c_row, r_row = _mla_block_decode(
                    p, cfg, carry, pos, c_row, r_row, rules=rules, moe=moe)
                return carry, (c_row, r_row)
            return body

        x, (c1, r1) = jax.lax.scan(
            body_factory(False), x,
            (params["dense_layers"], cache["c"][:nd], cache["rope"][:nd]))
        x, (c2, r2) = jax.lax.scan(
            body_factory(True), x,
            (params["moe_layers"], cache["c"][nd:], cache["rope"][nd:]))
        cache = {"c": jnp.concatenate([c1, c2]),
                 "rope": jnp.concatenate([r1, r2])}

    elif fam == "hybrid_ssm":
        s = cfg.ssm
        g = cfg.n_layers // s.attn_every

        def mamba_body(carry, layer):
            p, h_row, conv_row = layer
            y, st = ssd_mod.decode_mamba(
                p["m"], cfg, rms_norm(p["ln"], carry, cfg.norm_eps),
                {"h": h_row, "conv": conv_row}, rules=rules)
            return carry + y, (st["h"], st["conv"])

        def reshape_g(t):
            return t.reshape(g, s.attn_every, *t.shape[1:])

        def group_body(carry, layer):
            gparams, k_row, v_row, h_rows, conv_rows = layer
            carry, k_row, v_row = _block_decode(
                params["shared_attn"], cfg, carry, pos, k_row, v_row,
                rules=rules)
            carry, (h_rows, conv_rows) = jax.lax.scan(
                mamba_body, carry, (gparams, h_rows, conv_rows))
            return carry, (k_row, v_row, h_rows, conv_rows)

        x, (ks, vs, hs, convs) = jax.lax.scan(
            group_body, x,
            (params["mamba_groups"], cache["k"], cache["v"],
             reshape_g(cache["h"]), reshape_g(cache["conv"])))
        new_cache = {"k": ks, "v": vs,
                     "h": hs.reshape(g * s.attn_every, *hs.shape[2:]),
                     "conv": convs.reshape(g * s.attn_every,
                                           *convs.shape[2:])}
        if "mamba_tail" in params:
            x, (ht, ct) = jax.lax.scan(
                mamba_body, x,
                (params["mamba_tail"], cache["h_tail"], cache["conv_tail"]))
            new_cache["h_tail"] = ht
            new_cache["conv_tail"] = ct
        cache = new_cache

    elif fam == "rwkv":
        def body(carry, layer):
            p, s_row, lt, lc = layer
            h = rms_norm(p["ln1"], carry, cfg.norm_eps)
            y, (lt2, s_new) = rwkv_mod.apply_rwkv_time(
                p["time"], cfg, h, last=lt, state=s_row, rules=rules)
            carry = carry + y
            h = rms_norm(p["ln2"], carry, cfg.norm_eps)
            y, lc2 = rwkv_mod.apply_rwkv_channel(p["channel"], cfg, h,
                                                 last=lc, rules=rules)
            return carry + y, (s_new, lt2, lc2)

        x, (s, lt, lc) = jax.lax.scan(
            body, x, (params["layers"], cache["s"], cache["last_t"],
                      cache["last_c"]))
        cache = {"s": s, "last_t": lt, "last_c": lc}
    else:
        raise ValueError(fam)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(table, x)[:, 0]
    return logits, cache
