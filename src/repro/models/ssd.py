"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060), chunked.

Recurrence (per head h, scalar decay):  H_t = a_t * H_{t-1} + dt_t * B_t x_t^T
Output:                                  y_t = C_t @ H_t + D * x_t

Train/prefill uses the chunked algorithm: quadratic attention-like math
inside fixed-size chunks (MXU-friendly GEMMs) + a tiny `lax.scan` over chunk
states for the inter-chunk recurrence. Decode carries (H, conv window)
state — O(1) per token, which is what makes the hybrid arch long_500k-
eligible. The elementwise recurrence stays on the "electronic" side of the
DxPTA workload model; the in/out projections and intra-chunk GEMMs are the
photonic-offloadable part (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import NULL_RULES, shard

from .layers import DTYPE, _normal, init_rmsnorm, matmul32, rms_norm


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return s, d_in, n_heads, conv_dim


def init_mamba(key, cfg):
    s, d_in, n_heads, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        # fused input projection -> [z (gate), x, B, C, dt]
        "in_proj": _normal(ks[0], (d, 2 * d_in + 2 * s.d_state + n_heads),
                           d ** -0.5),
        "conv_w": _normal(ks[1], (s.d_conv, conv_dim), 0.2),
        "conv_b": jnp.zeros((conv_dim,), DTYPE),
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": init_rmsnorm(d_in),
        "out_proj": _normal(ks[2], (d_in, d), d_in ** -0.5),
    }


def mamba_specs(rules):
    return {"in_proj": rules.w_col, "conv_w": P_or_none(rules),
            "conv_b": rules.b_model, "a_log": rules.replicated,
            "d_skip": rules.replicated, "dt_bias": rules.replicated,
            "norm": {"scale": rules.b_model},
            "out_proj": rules.w_row}


def P_or_none(rules):
    from jax.sharding import PartitionSpec as P
    if rules.__class__.__name__ == "_NullRules":
        return None
    return P(None, rules.model_axis)


def _split_proj(cfg, proj):
    s, d_in, n_heads, _ = _dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * s.d_state], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over (B, S, C) with kernel (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _ssm_inputs(cfg, params, xbc, dt):
    s, d_in, n_heads, _ = _dims(cfg)
    x, bmat, cmat = jnp.split(xbc, [d_in, d_in + s.d_state], axis=-1)
    x = x.reshape(*x.shape[:2], n_heads, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])               # (B, S, H)
    a = jnp.exp(-jnp.exp(params["a_log"]) * dt)             # decay in (0, 1)
    return x, bmat, cmat, dt, a


def apply_mamba(params, cfg, x, rules=NULL_RULES, return_state=False):
    """Full-sequence chunked SSD. x: (B, S, D) -> (B, S, D)
    (or (out, state) when return_state — for prefill)."""
    s, d_in, n_heads, _ = _dims(cfg)
    b, true_seq, _ = x.shape
    q = s.chunk
    # Pad to a chunk multiple with decay-neutral steps: dt -> 0 gives a = 1
    # (state frozen) and zero input contribution, so the final state equals
    # the state at the true sequence end.
    pad = (-true_seq) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    seq = true_seq + pad
    proj = matmul32(x, params["in_proj"]).astype(x.dtype)
    z, xbc_raw, dt = _split_proj(cfg, proj)
    if pad:
        valid = (jnp.arange(seq) < true_seq)[None, :, None]
        dt = jnp.where(valid, dt, -30.0)  # softplus(-30) ~ 0
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs, bmat, cmat, dt, a = _ssm_inputs(cfg, params, xbc, dt)
    xs = shard(xs, rules.heads)

    nch = seq // q
    # chunk views
    xs_c = xs.reshape(b, nch, q, n_heads, s.head_dim).astype(jnp.float32)
    b_c = bmat.reshape(b, nch, q, s.d_state).astype(jnp.float32)
    c_c = cmat.reshape(b, nch, q, s.d_state).astype(jnp.float32)
    dt_c = dt.reshape(b, nch, q, n_heads)
    la = jnp.log(a.reshape(b, nch, q, n_heads))
    lcum = jnp.cumsum(la, axis=2)                           # (B, N, Q, H)

    # ---- intra-chunk (quadratic within chunk) ----
    # score[q_, t] = exp(lcum[q_] - lcum[t]) * (C_q . B_t) * dt_t,  t <= q_
    cb = jnp.einsum("bnqs,bnts->bnqt", c_c, b_c)            # (B, N, Q, Q)
    decay = jnp.exp(lcum[:, :, :, None, :] - lcum[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((q, q), bool))
    score = jnp.where(tri[None, None, :, :, None],
                      cb[..., None] * decay, 0.0)           # (B,N,Q,T,H)
    y_intra = jnp.einsum("bnqth,bnth,bnthd->bnqhd", score, dt_c, xs_c)

    # ---- chunk summary states ----
    # S_n = sum_t exp(lcum_end - lcum_t) * dt_t * B_t x_t^T   (B,N,H,S,Dh)
    wdec = jnp.exp(lcum[:, :, -1:, :] - lcum) * dt_c        # (B, N, Q, H)
    state_c = jnp.einsum("bnth,bnts,bnthd->bnhsd", wdec, b_c, xs_c)
    a_chunk = jnp.exp(lcum[:, :, -1, :])                    # (B, N, H)

    # ---- inter-chunk recurrence over the N chunks ----
    def step(h_prev, inp):
        st, ac = inp                                        # (B,H,S,Dh), (B,H)
        h_new = h_prev * ac[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((b, n_heads, s.d_state, s.head_dim), jnp.float32)
    h_final, h_before = jax.lax.scan(
        step, h0, (jnp.moveaxis(state_c, 1, 0), jnp.moveaxis(a_chunk, 1, 0)))
    h_before = jnp.moveaxis(h_before, 0, 1)                 # (B,N,H,S,Dh)

    # y_inter[t] = exp(lcum_t) * C_t @ H_{chunk_start}
    y_inter = jnp.einsum("bnqs,bnhsd,bnqh->bnqhd", c_c, h_before,
                         jnp.exp(lcum))
    y = (y_intra + y_inter).reshape(b, seq, n_heads, s.head_dim)
    y = y + params["d_skip"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(b, seq, d_in)

    # gated RMSNorm + output projection
    y = rms_norm(params["norm"],
                 (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 cfg.norm_eps)
    out = matmul32(y, params["out_proj"]).astype(x.dtype)
    out = out[:, :true_seq]
    if return_state:
        state = {"h": h_final,
                 "conv": xbc_raw[:, true_seq - (s.d_conv - 1):true_seq, :]}
        return out, state
    return out


def init_mamba_state(cfg, batch):
    s, d_in, n_heads, conv_dim = _dims(cfg)
    return {
        "h": jnp.zeros((batch, n_heads, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), DTYPE),
    }


def decode_mamba(params, cfg, x, state, rules=NULL_RULES):
    """One-token step. x: (B, 1, D); state from init_mamba_state."""
    s, d_in, n_heads, conv_dim = _dims(cfg)
    proj = matmul32(x, params["in_proj"]).astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, proj)
    window = jnp.concatenate([state["conv"], xbc], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"]) \
        + params["conv_b"]
    xbc1 = jax.nn.silu(conv_out.astype(jnp.float32)
                       ).astype(x.dtype)[:, None, :]
    xs, bmat, cmat, dtv, a = _ssm_inputs(cfg, params, xbc1, dt)
    xf = xs[:, 0].astype(jnp.float32)                       # (B, H, Dh)
    h = state["h"] * a[:, 0, :, None, None] + jnp.einsum(
        "bh,bs,bhd->bhsd", dtv[:, 0], bmat[:, 0].astype(jnp.float32), xf)
    y = jnp.einsum("bs,bhsd->bhd", cmat[:, 0].astype(jnp.float32), h) \
        + params["d_skip"][:, None] * xf
    y = y.reshape(x.shape[0], 1, d_in)
    y = rms_norm(params["norm"],
                 (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 cfg.norm_eps)
    out = matmul32(y, params["out_proj"]).astype(x.dtype)
    return out, {"h": h, "conv": window[:, 1:, :]}
