"""Mixture-of-Experts FFN with sort-based expert-parallel dispatch.

Routing: softmax top-k (OLMoE) or sigmoid + aux-loss-free bias top-k with a
shared expert (DeepSeek-V3). Dispatch: token->expert assignment is flattened,
sorted by expert id, packed into a capacity-bounded (E, C, D) tensor (tokens
over capacity drop to the residual path, standard GShard semantics), run
through batched expert GEMMs (einsum over the expert axis — shards cleanly
as EP over the model axis), and scattered back with routing weights.

No torch.distributed-style all-to-all is written by hand: the gather/scatter
with globally-sharded indices lowers to XLA collectives under GSPMD
(DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import NULL_RULES, shard

from .layers import DTYPE, _normal, apply_mlp, einsum32, init_mlp, mlp_specs


def _round_up(x, m):
    return ((x + m - 1) // m) * m


def init_moe(key, cfg):
    mo = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": _normal(ks[0], (d, mo.n_experts), d ** -0.5).astype(jnp.float32),
        "wi": _normal(ks[1], (mo.n_experts, d, mo.d_expert), d ** -0.5),
        "wg": _normal(ks[2], (mo.n_experts, d, mo.d_expert), d ** -0.5),
        "wo": _normal(ks[3], (mo.n_experts, mo.d_expert, d),
                      mo.d_expert ** -0.5),
    }
    if mo.aux_free_bias:
        p["route_bias"] = jnp.zeros((mo.n_experts,), jnp.float32)
    if mo.n_shared:
        p["shared"] = init_mlp(ks[4], d, (mo.d_shared or mo.d_expert)
                               * mo.n_shared)
    return p


def moe_specs(cfg, rules):
    s = {"router": rules.replicated, "wi": rules.w_expert_in,
         "wg": rules.w_expert_in, "wo": rules.w_expert_out,
         "route_bias": rules.replicated}
    if cfg.moe.n_shared:
        s["shared"] = mlp_specs(rules)
    return s


def route(params, cfg, xf):
    """xf: (T, D) f32 -> (weights (T, k) f32, expert_ids (T, k) i32, aux)."""
    mo = cfg.moe
    logits = xf @ params["router"]                      # (T, E) f32
    if mo.aux_free_bias:
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["route_bias"]             # bias steers routing
        _, ids = jax.lax.top_k(sel, mo.top_k)
        w = jnp.take_along_axis(scores, ids, axis=-1)   # weights exclude bias
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
        w = w * mo.route_scale
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, mo.top_k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss (monitored; optional in training).
    load = jnp.mean(jax.nn.one_hot(ids[:, 0], mo.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    aux = mo.n_experts * jnp.sum(load * imp)
    return w, ids, aux


def apply_moe(params, cfg, x, rules=NULL_RULES):
    """x: (B, S, D) -> (B, S, D), aux scalar."""
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    w, ids, aux = route(params, cfg, xf.astype(jnp.float32))

    k = mo.top_k
    e_flat = ids.reshape(t * k)
    tok_flat = jnp.repeat(jnp.arange(t), k)
    w_flat = w.reshape(t * k).astype(DTYPE)

    order = jnp.argsort(e_flat)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    w_sorted = w_flat[order]

    cap = _round_up(int(t * k / mo.n_experts * mo.capacity_factor) or 1, 8)
    starts = jnp.searchsorted(e_sorted, jnp.arange(mo.n_experts))
    pos_in_e = jnp.arange(t * k) - starts[e_sorted]
    keep = pos_in_e < cap
    dest = e_sorted * cap + jnp.clip(pos_in_e, 0, cap - 1)

    xg = jnp.take(xf, tok_sorted, axis=0) * keep[:, None].astype(xf.dtype)
    buf = jnp.zeros((mo.n_experts * cap, d), xf.dtype).at[dest].add(
        jnp.where(keep[:, None], xg, 0))
    buf = shard(buf.reshape(mo.n_experts, cap, d), rules.expert_tokens)

    h = einsum32("ecd,edf->ecf", buf, params["wi"]).astype(buf.dtype)
    g = einsum32("ecd,edf->ecf", buf, params["wg"]).astype(buf.dtype)
    y = einsum32("ecf,efd->ecd", h * jax.nn.silu(g),
                 params["wo"]).astype(buf.dtype)
    y = shard(y, rules.expert_tokens).reshape(mo.n_experts * cap, d)

    y_sorted = jnp.take(y, dest, axis=0) * (w_sorted * keep)[:, None]
    out = jnp.zeros((t, d), y.dtype).at[tok_sorted].add(y_sorted)
    out = out.reshape(b, s, d).astype(x.dtype)
    if mo.n_shared:
        out = out + apply_mlp(params["shared"], x, rules=rules)
    return out, aux


# ---------------------------------------------------------------------------
# Cumsum (sort-free) dispatch — hillclimb alternative (EXPERIMENTS §Perf)
# ---------------------------------------------------------------------------

DISPATCH_MODE = "sort"  # "sort" (baseline) | "cumsum" (GShard-style)


def apply_moe_cumsum(params, cfg, x, rules=NULL_RULES, groups: int = 1):
    """GShard-style capacity dispatch: tokens stay in `groups` fixed groups
    (one per data shard), position-in-expert comes from a per-group cumsum
    over one-hot assignments — no global sort, so the only cross-device
    traffic is the expert-parallel redistribution of the (G, E, C, D)
    buffer itself.
    """
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    w, ids, aux = route(params, cfg, xf.astype(jnp.float32))

    k = mo.top_k
    if t % groups:
        groups = 1
    g_sz = t * k // groups
    cap = _round_up(int(g_sz / mo.n_experts * mo.capacity_factor) or 1, 8)

    onehot = jax.nn.one_hot(ids.reshape(groups, g_sz), mo.n_experts,
                            dtype=jnp.int32)                  # (G, gk, E)
    pos = jnp.cumsum(onehot, axis=1) - 1                      # pos in expert
    pos = jnp.sum(pos * onehot, axis=-1)                      # (G, gk)
    e_flat = ids.reshape(groups, g_sz)
    keep = pos < cap
    dest = e_flat * cap + jnp.clip(pos, 0, cap - 1)           # (G, gk)

    tok_local = jnp.broadcast_to(
        jnp.repeat(jnp.arange(t // groups), k)[None], (groups, g_sz))
    xg = xf.reshape(groups, t // groups, d)
    gathered = jnp.take_along_axis(
        xg, tok_local[..., None], axis=1) * keep[..., None].astype(xf.dtype)

    buf = jnp.zeros((groups, mo.n_experts * cap, d), xf.dtype)
    buf = jax.vmap(lambda bb, dd, vv: bb.at[dd].add(vv))(buf, dest, gathered)
    buf = buf.reshape(groups, mo.n_experts, cap, d)
    buf = shard(buf, _group_spec(rules))

    h = einsum32("gecd,edf->gecf", buf, params["wi"]).astype(buf.dtype)
    gate = einsum32("gecd,edf->gecf", buf, params["wg"]).astype(buf.dtype)
    y = einsum32("gecf,efd->gecd", h * jax.nn.silu(gate),
                 params["wo"]).astype(buf.dtype)
    y = shard(y, _group_spec(rules)).reshape(groups, mo.n_experts * cap, d)

    y_tok = jax.vmap(lambda yy, dd: jnp.take(yy, dd, axis=0))(y, dest)
    y_tok = y_tok * (w.reshape(groups, g_sz).astype(y.dtype)
                     * keep.astype(y.dtype))[..., None]
    out = jnp.zeros((groups, t // groups, d), y.dtype)
    out = jax.vmap(lambda oo, tt, vv: oo.at[tt].add(vv))(out, tok_local,
                                                         y_tok)
    out = out.reshape(b, s, d).astype(x.dtype)
    if mo.n_shared:
        out = out + apply_mlp(params["shared"], x, rules=rules)
    return out, aux


def _group_spec(rules):
    """(G, E, C, D) spec: groups over data, experts over EP axes. Axes used
    by EP are excluded from the group dim (serving-time EP can span the
    whole mesh, and a mesh axis may shard only one dim)."""
    if rules.__class__.__name__ == "_NullRules":
        return None
    from jax.sharding import PartitionSpec as P
    ep = rules.ep_axes
    d_axes = tuple(a for a in (rules._d() or ()) if a not in ep)
    return P(d_axes or None, ep, None, None)


def _group_local_spec(rules):
    """(G, E, C, D) group-local layout. NOTE: forcing scatter/gather onto
    this layout with an extra reshard was tried and REFUTED (EXPERIMENTS
    §Perf H1-iter5): GSPMD lowers the reshard as all-gather, a net loss.
    Kept for reference."""
    if rules.__class__.__name__ == "_NullRules":
        return None
    from jax.sharding import PartitionSpec as P
    return P(rules._d(), None, None, None)


def apply_moe_dispatch(params, cfg, x, rules=NULL_RULES, groups: int = 1,
                       mode=None):
    mode = mode or DISPATCH_MODE
    if mode == "cumsum":
        return apply_moe_cumsum(params, cfg, x, rules, groups)
    return apply_moe(params, cfg, x, rules)
