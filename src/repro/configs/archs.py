"""The 10 assigned architectures as published configs + reduced smoke configs.

Sources per the assignment sheet (hf / arXiv ids inline). Full configs are
exercised abstractly via the dry-run only; `reduced()` variants run real
forward/train steps on CPU in the smoke tests.
"""
from __future__ import annotations

import dataclasses

from .base import MLAConfig, MoEConfig, ModelConfig, SSMConfig

# --- llava-next-34b [vlm] — hf:llava-hf/llava-v1.6 (34B backbone) ---------
LLAVA_NEXT_34B = ModelConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20480, vocab=64000,
    rope_theta=5e6, n_prefix_embeds=576)  # anyres tiling frontend stubbed

# --- zamba2-7b [hybrid] — arXiv:2411.15242 --------------------------------
ZAMBA2_7B = ModelConfig(
    name="zamba2-7b", family="hybrid_ssm", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128,
                  attn_every=6))

# --- olmoe-1b-7b [moe] — arXiv:2409.02060 ---------------------------------
OLMOE_1B_7B = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1024, vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024))

# --- deepseek-v3-671b [moe+MLA] — arXiv:2412.19437 ------------------------
DEEPSEEK_V3_671B = ModelConfig(
    name="deepseek-v3-671b", family="mla_moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv_heads=128, d_ff=2048, vocab=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  first_dense_layers=3, d_shared=2048, route_scale=2.5,
                  aux_free_bias=True),
    mtp_depth=1)

# --- gemma3-4b [dense] — hf:google/gemma-3 family -------------------------
GEMMA3_4B = ModelConfig(
    name="gemma3-4b", family="dense", n_layers=34, d_model=2560,
    n_heads=8, n_kv_heads=4, head_dim=256, d_ff=10240, vocab=262144,
    sliding_window=1024, swa_pattern=6,  # 5 local : 1 global, 128k context
    rope_theta=1e6, tie_embeddings=True)

# --- h2o-danube-1.8b [dense] — arXiv:2401.16818 ---------------------------
H2O_DANUBE_1_8B = ModelConfig(
    name="h2o-danube-1.8b", family="dense", n_layers=24, d_model=2560,
    n_heads=32, n_kv_heads=8, head_dim=80, d_ff=6912, vocab=32000,
    sliding_window=4096, swa_pattern=0)  # mistral-style all-layer SWA

# --- granite-3-2b [dense] — hf:ibm-granite/granite-3.0-2b-base ------------
GRANITE_3_2B = ModelConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, head_dim=64, d_ff=8192, vocab=49155,
    tie_embeddings=True)

# --- qwen2.5-3b [dense] — hf:Qwen/Qwen2.5 family --------------------------
QWEN2_5_3B = ModelConfig(
    name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
    n_heads=16, n_kv_heads=2, head_dim=128, d_ff=11008, vocab=151936,
    qkv_bias=True, rope_theta=1e6)

# --- seamless-m4t-medium [audio enc-dec] — arXiv:2308.11596 ---------------
SEAMLESS_M4T_MEDIUM = ModelConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab=256206,
    enc_layers=12, dec_layers=12, n_prefix_embeds=0)  # audio frontend stubbed

# --- rwkv6-7b [attention-free] — arXiv:2404.05892 (Finch) -----------------
RWKV6_7B = ModelConfig(
    name="rwkv6-7b", family="rwkv", n_layers=32, d_model=4096,
    n_heads=64, n_kv_heads=64, head_dim=64, d_ff=14336, vocab=65536)


ARCHS = {
    c.name: c for c in (
        LLAVA_NEXT_34B, ZAMBA2_7B, OLMOE_1B_7B, DEEPSEEK_V3_671B, GEMMA3_4B,
        H2O_DANUBE_1_8B, GRANITE_3_2B, QWEN2_5_3B, SEAMLESS_M4T_MEDIUM,
        RWKV6_7B)
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: few layers, narrow width,
    few experts, small vocab — structure (GQA ratios, MoE routing, SWA
    pattern, MLA ranks, SSM interleave) preserved."""
    kw = dict(
        name=cfg.name + "-reduced", n_layers=min(cfg.n_layers, 4),
        d_model=128, d_ff=256, vocab=512,
        n_heads=max(4, min(cfg.n_heads, 8)),
        head_dim=32)
    kw["n_kv_heads"] = max(1, kw["n_heads"] // max(
        1, cfg.n_heads // max(cfg.n_kv_heads, 1)))
    if cfg.sliding_window:
        kw["sliding_window"] = 16
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2), d_expert=64,
            d_shared=64 if cfg.moe.n_shared else 0,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1))
    if cfg.mla:
        kw["mla"] = MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                              rope_head_dim=16, nope_head_dim=32,
                              v_head_dim=32)
        kw["head_dim"] = 0
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32,
                                        chunk=8, attn_every=2)
        kw["n_layers"] = 5  # two shared-attn applications + tail layers
    if cfg.family == "encdec":
        kw["enc_layers"] = 2
        kw["dec_layers"] = 2
        kw["n_layers"] = 4
    if cfg.family == "vlm":
        kw["n_prefix_embeds"] = 8
    if cfg.mtp_depth:
        kw["mtp_depth"] = 1
    return dataclasses.replace(cfg, **kw)
