"""Model + run configuration system.

One `ModelConfig` covers every assigned architecture family (dense GQA,
sliding-window, MLA+MoE, plain MoE, Mamba2 hybrid, RWKV6, enc-dec, VLM
backbone). Family-specific fields are ignored by other families. Every arch
module in repro.configs exposes:

    CONFIG            — the full published configuration
    reduced()         — a tiny same-family config for CPU smoke tests

`SHAPES` defines the assigned input-shape set; `input_specs()` lives in
repro.launch.dryrun (it needs shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0            # per-expert FFN hidden size
    n_shared: int = 0            # shared (always-on) experts
    first_dense_layers: int = 0  # leading layers that use a dense FFN
    d_shared: int = 0            # shared-expert hidden (defaults d_expert)
    capacity_factor: float = 1.25
    route_scale: float = 1.0
    aux_free_bias: bool = False  # DeepSeek-V3 aux-loss-free load balancing


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 0         # 0 -> full-rank Q projection
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    attn_every: int = 6          # zamba2: shared attn block period (0 = none)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | mla_moe | hybrid_ssm | rwkv
                                 # | encdec | vlm
    n_layers: int = 12
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 2048
    vocab: int = 32000
    qkv_bias: bool = False
    act: str = "silu"            # gated GLU activation
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # sliding-window attention: 0 = all-global. `swa_pattern = p` means every
    # p-th layer (1-indexed) is global, the rest local (gemma3: p=6);
    # p = 1 with sliding_window>0 would be all-global; use swa_pattern=0 for
    # "every layer local" (h2o-danube).
    sliding_window: int = 0
    swa_pattern: int = 0
    attn_logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # vlm / audio stub frontend: number of precomputed embedding positions
    # that prefix the token sequence (0 = pure LM)
    n_prefix_embeds: int = 0
    # DeepSeek multi-token prediction depth (0 = off)
    mtp_depth: int = 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic path exists (long_500k eligibility, DESIGN.md §5)."""
        if self.family in ("hybrid_ssm", "rwkv"):
            return True
        # SWA-dominant: bounded KV on all/most layers.
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, dh = self.d_model, self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv":
            per = d * d * 4 + d * self.d_ff * 2 + d * 12  # r,k,v,g,o + cmix
            return emb + self.n_layers * per
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * dh \
            + self.n_heads * dh * d
        if self.mla is not None:
            m = self.mla
            q_in = m.q_lora_rank or d
            attn = (d * m.q_lora_rank if m.q_lora_rank else 0) \
                + q_in * self.n_heads * (m.nope_head_dim + m.rope_head_dim) \
                + d * (m.kv_lora_rank + m.rope_head_dim) \
                + m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim) \
                + self.n_heads * m.v_head_dim * d
        ffn_dense = 3 * d * self.d_ff
        layers = self.enc_layers + self.dec_layers or self.n_layers
        if self.family in ("moe", "mla_moe") and self.moe:
            mo = self.moe
            moe_ffn = 3 * d * mo.d_expert * mo.n_experts \
                + 3 * d * (mo.d_shared or mo.d_expert) * mo.n_shared \
                + d * mo.n_experts
            n_moe = layers - mo.first_dense_layers
            return emb + mo.first_dense_layers * (attn + ffn_dense) \
                + n_moe * (attn + moe_ffn)
        if self.family == "hybrid_ssm":
            s = self.ssm or SSMConfig()
            d_in = s.expand * d
            per = 2 * d * d_in + d_in * s.d_conv + d_in * d \
                + (d_in // s.head_dim) * (2 + s.d_state * 0)
            n_attn = (self.n_layers // max(s.attn_every, 1)) and 1
            return emb + self.n_layers * per + (attn + ffn_dense)  # shared blk
        if self.family == "encdec":
            cross = attn
            return emb + self.enc_layers * (attn + ffn_dense) \
                + self.dec_layers * (attn + cross + ffn_dense)
        return emb + layers * (attn + ffn_dense)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if self.family not in ("moe", "mla_moe") or not self.moe:
            return self.param_count()
        full = self.param_count()
        mo = self.moe
        d = self.d_model
        layers = self.n_layers - mo.first_dense_layers
        all_experts = 3 * d * mo.d_expert * mo.n_experts * layers
        active = 3 * d * mo.d_expert * mo.top_k * layers
        return full - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"
    # Decode length: how many tokens each sequence generates against the
    # seq_len context ("decode" kind only; train/prefill ignore it). The
    # default matches the value `workload_for` historically hard-coded, so
    # the assigned shape set extracts identically to before the field
    # existed.
    new_tokens: int = 32


# The assigned LM shape set (identical across the 10 archs).
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
