"""Architecture registry: `--arch <id>` resolves here."""
from .archs import ARCHS, reduced
from .base import (SHAPES, SHAPES_BY_NAME, MLAConfig, MoEConfig, ModelConfig,
                   ShapeConfig, SSMConfig)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


__all__ = ["ARCHS", "SHAPES", "SHAPES_BY_NAME", "MLAConfig", "MoEConfig",
           "ModelConfig", "SSMConfig", "ShapeConfig", "get_config",
           "list_archs", "reduced"]
