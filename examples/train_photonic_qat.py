"""Train a small LM for a few hundred steps with photonic-aware QAT.

The HW/SW-co-design SW half: the model trains *through* the 4-bit DDot
quantization (straight-through estimator) so its weights adapt to the found
PTA's precision. Demonstrates the full trainer substrate (checkpointing,
auto-resume, deterministic data) on CPU.

    PYTHONPATH=src python examples/train_photonic_qat.py --steps 50
    # (defaults are sized for this CPU container; --d-model 768 --layers 12
    #  --steps 300 gives the ~100M-param run on real hardware)
"""
import argparse
import dataclasses

import jax

from repro.configs import ModelConfig
from repro.configs.base import ShapeConfig
from repro.models.layers import set_exec_safe
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_qat_ckpt")
    args = ap.parse_args()
    set_exec_safe(True)

    cfg = ModelConfig(name="qat-lm", family="dense", n_layers=args.layers,
                      d_model=args.d_model, n_heads=max(4, args.d_model // 32),
                      n_kv_heads=max(2, args.d_model // 64), head_dim=32,
                      d_ff=args.d_model * 4, vocab=2048)
    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=10,
                         ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, shape, tcfg=tcfg,
                      opt_cfg=adamw.AdamWConfig(lr=1e-3, warmup_steps=10,
                                                total_steps=args.steps))
    if trainer.start_step:
        print(f"resumed from checkpoint at step {trainer.start_step}")
    out = trainer.run()
    losses = out["losses"]
    print(f"steps {trainer.start_step}..{out['final_step']}  "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}  "
          f"stragglers={out['straggler_steps']}")
    assert losses[-1] < losses[0], "loss should decrease"
    print("checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
