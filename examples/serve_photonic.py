"""End-to-end driver (deliverable (b)): serve a small model with batched
requests through the photonic-simulation path.

The paper is an inference-accelerator DSE paper, so the e2e driver is a
*server*: (1) DxPTA searches a PTA for the serving workload, (2) the model
serves batched requests on this host, with its GEMMs optionally routed
through the DDot Pallas kernel (4-bit photonic functional simulation), and
(3) the DxPTA cost model reports what the same batch costs on the found PTA.

    PYTHONPATH=src python examples/serve_photonic.py [--arch qwen2.5-3b]
        [--photonic]   # route the LM head through kernels.photonic_matmul
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import get_config, list_archs, reduced
from repro.models.layers import set_exec_safe
from repro.train.serve import Request, Server, photonic_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--photonic", action="store_true",
                    help="4-bit DDot-kernel logits (functional PTA sim)")
    args = ap.parse_args()
    set_exec_safe(True)

    cfg = reduced(get_config(args.arch))
    print(f"model: {cfg.name} ({cfg.family}), vocab={cfg.vocab}")
    params = M.init_params(jax.random.key(0), cfg)

    srv = Server(cfg, params, batch_size=args.batch, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=rng.integers(4, 12)
                                        ).astype(np.int32),
                    max_new=args.max_new) for _ in range(args.batch)]
    stats = srv.generate(reqs)
    print(f"served {len(reqs)} requests, {stats['tokens']} tokens: "
          f"ttft={stats['ttft_s']*1e3:.1f} ms, "
          f"decode={stats['decode_s_per_tok']*1e3:.2f} ms/tok (host CPU)")
    print("sample output tokens:", reqs[0].out)

    if args.photonic:
        from repro.kernels import photonic_matmul
        x = jax.random.normal(jax.random.key(1), (args.batch, cfg.d_model),
                              jnp.float32)
        t0 = time.perf_counter()
        logits_q = photonic_matmul(x, params["embed"]["table"].T
                                   .astype(jnp.float32), 0.02, True, 7)
        logits_f = x @ np.asarray(params["embed"]["table"].T, np.float32)
        err = float(jnp.linalg.norm(logits_q - logits_f)
                    / jnp.linalg.norm(logits_f))
        print(f"photonic (4-bit DDot kernel + shot noise) LM head: "
              f"rel_err={err:.3f} vs fp32  "
              f"({(time.perf_counter()-t0)*1e3:.0f} ms interpret-mode)")

    print("\n== DxPTA co-design report: this workload on the found PTA ==")
    rep = photonic_report(get_config(args.arch), seq_len=64,
                          batch=args.batch, new_tokens=args.max_new)
    for k, v in rep.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
