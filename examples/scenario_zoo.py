"""Scenario co-search across the model zoo (repro.scenarios).

Expands a model x shape grid — every architecture family, prefill vs
decode vs train — lowers each cell through the config->workload
extractor, and co-searches all of them through one resident
`SearchService`. The report at the end is the HW/SW co-design payoff:
per-scenario winning PTA configs plus the cross-class summary showing
which architecture parameter decode's tiny-M GEMMs re-negotiate against
prefill's large-M ones (the paper's Alg. 1 significance question,
answered empirically per scenario class).

    PYTHONPATH=src python examples/scenario_zoo.py            # reduced zoo
    PYTHONPATH=src python examples/scenario_zoo.py --full     # real configs
"""
import argparse
import time

from repro.configs import list_archs
from repro.core import Constraints
from repro.scenarios import ScenarioGrid, sweep
from repro.serve import SearchService


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="sweep the published configs (slower) instead of "
                         "the reduced CPU-smoke ones")
    ap.add_argument("--engine", default="numpy",
                    choices=("numpy", "jax", "pallas"))
    ap.add_argument("--n-z", type=int, default=6)
    args = ap.parse_args()

    grid = ScenarioGrid.zoo(
        kinds=("train", "prefill", "decode"),
        seq_lens=(2048,), batches=(8,), new_tokens=(16, 64),
        reduce=not args.full)
    print(f"model zoo: {len(list_archs())} archs -> {grid.size} scenarios")

    # Serving classes carry tighter latency budgets than training runs —
    # the per-class box mapping expresses that directly.
    boxes = {"train": Constraints(),
             "prefill": Constraints(latency_ms=8.0),
             "decode": Constraints(latency_ms=5.0)}

    svc = SearchService(n_z=args.n_z, engine=args.engine)
    t0 = time.perf_counter()
    report = sweep(grid, boxes, service=svc)
    print(f"cold sweep: {(time.perf_counter() - t0) * 1e3:.1f}ms")
    print(report.format())

    # The same grid again: every scenario is a canonical-key memo hit.
    t0 = time.perf_counter()
    again = sweep(grid, boxes, service=svc)
    print(f"repeat sweep: {(time.perf_counter() - t0) * 1e3:.1f}ms, "
          f"{again.stats['memo_hits']}/{len(again.results)} memoized")


if __name__ == "__main__":
    main()
