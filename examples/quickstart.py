"""Quickstart: run the DxPTA methodology end to end on a paper workload.

    PYTHONPATH=src python examples/quickstart.py [--workload deit-b]

Steps (mirrors Fig. 4): 1) significance analysis (Alg. 1), 2) constraint-
aware search (Alg. 2), 3) compare against the exhaustive optimum, 4) report
the found PTA.
"""
import argparse

from repro.core import (Constraints, PAPER_WORKLOADS, dxpta_search,
                        grid_search_vectorized, observe_significance,
                        significant_params)
from repro.core.paper_workloads import load


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="deit-b",
                    choices=sorted(PAPER_WORKLOADS))
    ap.add_argument("--area", type=float, default=50.0)
    ap.add_argument("--power", type=float, default=5.0)
    ap.add_argument("--energy", type=float, default=50.0)
    ap.add_argument("--latency", type=float, default=10.0)
    args = ap.parse_args()

    print("== Step 1: parameter significance (Alg. 1) ==")
    scores = observe_significance()
    for name, s in scores.items():
        print(f"  S({name}): area x{s.s_area:.3f}, power x{s.s_power:.3f}")
    print(f"  fine-grained candidates for: {significant_params(scores)}")

    cons = Constraints(area_mm2=args.area, power_w=args.power,
                       energy_mj=args.energy, latency_ms=args.latency)
    wl = load(args.workload)
    print(f"\n== Step 2: constraint-aware search (Alg. 2) on {wl.name} ==")
    print(f"  constraints: {cons}")
    r = dxpta_search(wl, cons, significance=scores)
    if not r.feasible:
        print("  NO feasible config under these constraints.")
        return
    print(f"  found: {r.best_cfg}")
    print(f"  area={r.area_mm2:.1f} mm^2  power={r.power_w:.2f} W  "
          f"energy={r.energy_j*1e3:.1f} mJ  latency={r.latency_s*1e3:.2f} ms")
    print(f"  evaluated {r.n_evaluated} configs "
          f"({r.n_workload_evals} workload evals) in {r.wall_time_s:.2f}s")

    print("\n== Step 3: exhaustive optimum (vectorized, beyond-paper) ==")
    ex = grid_search_vectorized(wl, cons)
    print(f"  exhaustive best: {ex.best_cfg}  EDP ratio "
          f"dxpta/exh = {r.edp/ex.edp:.3f}  ({ex.wall_time_s*1e3:.0f} ms "
          f"for all {ex.n_evaluated} configs)")


if __name__ == "__main__":
    main()
