"""Beyond-paper example: DxPTA co-search on the unified engine layer.

Two modes:

  * Default — one searched PTA per (arch, shape) across the framework's
    model zoo, via the config->workload extractor (repro.core.extract).
    `--engine` picks any SearchEngine backend (python is the paper-faithful
    Alg. 2 loop; numpy/jax/pallas are the vectorized ones).

        PYTHONPATH=src python examples/arch_cosearch.py --engine numpy

  * `--scenarios` — constraint-scenario sweep over the five paper workloads
    (DeiT-T/S/B, BERT-B/L): every (area, power) box is one batched
    `search_workloads` call, which on the pallas engine evaluates all five
    workloads against the full grid in a single fused kernel launch.
    Constraints are dynamic kernel operands, so the whole sweep reuses one
    jit cache entry — no recompiles between scenarios.

        PYTHONPATH=src python examples/arch_cosearch.py --scenarios \
            --engine pallas

  * `--scenarios --pareto` — the same sweep in frontier mode: each scenario
    returns every workload's whole area/power/EDP Pareto frontier
    (objective="pareto") instead of the single min-EDP point, so one run
    maps the full trade-off surface per constraint box. On pallas the
    per-block dominance reduction for all five workloads still shares one
    fused launch per scenario.

        PYTHONPATH=src python examples/arch_cosearch.py --scenarios \
            --pareto --engine pallas
"""
import argparse
import time

from repro.configs import SHAPES_BY_NAME, get_config, list_archs
from repro.configs.base import ShapeConfig
from repro.core import Constraints, ENGINES, dxpta_search, search_workloads
from repro.core.extract import workload_for
from repro.core.paper_workloads import PAPER_WORKLOADS

# (area mm^2, power W) boxes swept in --scenarios mode; the first is the
# paper's constraint set.
SCENARIOS = [(50.0, 5.0), (40.0, 4.0), (30.0, 3.0), (60.0, 8.0),
             (25.0, 2.5)]


def sweep_archs(args):
    if args.shape == "serve_2k":
        # laptop-scale default: 2k-token prefill, batch 1
        shape = ShapeConfig("serve_2k", seq_len=2048, global_batch=1,
                            kind="prefill")
    else:
        shape = SHAPES_BY_NAME[args.shape]
    cons = Constraints(area_mm2=args.area, power_w=args.power,
                       energy_mj=1e9, latency_ms=1e9)  # A/P-bounded search
    print(f"shape={shape.name}  engine={args.engine}  constraints: "
          f"{args.area}mm^2 {args.power}W "
          f"(energy/latency unconstrained -> min-EDP inside the A/P box)")
    print(f"{'arch':24s} {'feasible':8s} {'config':34s} "
          f"{'E[mJ]':>9s} {'L[ms]':>9s}")
    for arch in list_archs():
        cfg = get_config(arch)
        wl = workload_for(cfg, shape)
        r = dxpta_search(wl, cons, engine=args.engine)
        if r.feasible:
            print(f"{arch:24s} {'yes':8s} {str(r.best_cfg):34s} "
                  f"{r.energy_j*1e3:9.1f} {r.latency_s*1e3:9.2f}")
        else:
            print(f"{arch:24s} {'NO':8s} {'-':34s} {'-':>9s} {'-':>9s}")


def sweep_scenarios(args):
    wls = {name: f() for name, f in PAPER_WORKLOADS.items()}
    objective = "pareto" if args.pareto else "edp"
    print(f"engine={args.engine}  objective={objective}  batched search: "
          f"{len(wls)} paper workloads x full 12^5 grid per constraint "
          f"scenario")
    for area, power in SCENARIOS:
        cons = Constraints(area_mm2=area, power_w=power)
        t0 = time.perf_counter()
        res = search_workloads(wls, cons, engine=args.engine,
                               hierarchical=True, objective=objective)
        dt = time.perf_counter() - t0
        print(f"\n-- scenario: {area:.0f}mm^2 / {power:.1f}W "
              f"(one launch, {dt*1e3:.0f}ms)")
        for name, r in res.items():
            if not r.feasible:
                print(f"  {name:8s} infeasible under this box")
            elif args.pareto:
                lo, hi = r.metrics["edp"].min(), r.metrics["edp"].max()
                a_lo, a_hi = r.metrics["area"].min(), r.metrics["area"].max()
                print(f"  {name:8s} frontier: {r.size:3d} configs  "
                      f"area {a_lo:.1f}..{a_hi:.1f}mm^2  "
                      f"EDP {lo:.3e}..{hi:.3e} ({r.n_feasible} feasible)")
            else:
                print(f"  {name:8s} {str(r.best_cfg):34s} "
                      f"EDP={r.edp:.3e} ({r.n_feasible} feasible)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", default="serve_2k",
                    choices=["serve_2k", *sorted(SHAPES_BY_NAME)])
    ap.add_argument("--area", type=float, default=50.0)
    ap.add_argument("--power", type=float, default=5.0)
    ap.add_argument("--engine", default="numpy", choices=sorted(ENGINES))
    ap.add_argument("--scenarios", action="store_true",
                    help="constraint-scenario sweep over the paper "
                         "workloads (batched search_workloads)")
    ap.add_argument("--pareto", action="store_true",
                    help="with --scenarios: return each workload's whole "
                         "area/power/EDP frontier per scenario instead of "
                         "the min-EDP point")
    args = ap.parse_args()
    if args.pareto and not args.scenarios:
        ap.error("--pareto requires --scenarios")
    if args.scenarios:
        sweep_scenarios(args)
    else:
        sweep_archs(args)


if __name__ == "__main__":
    main()
