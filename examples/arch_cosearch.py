"""Beyond-paper example: DxPTA across the 10 assigned architectures x
deployment shapes — one searched PTA per (arch, shape), with Pareto fronts.

The paper searches for DeiT/BERT only; this extends the methodology to the
framework's whole model zoo via the config->workload extractor
(repro.core.extract) and prints which deployments are photonic-feasible
under the paper's constraints.

    PYTHONPATH=src python examples/arch_cosearch.py [--shape prefill_32k]
"""
import argparse

from repro.configs import SHAPES_BY_NAME, get_config, list_archs
from repro.core import Constraints, dxpta_search
from repro.core.extract import workload_for
from repro.configs.base import ShapeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", default="serve_2k",
                    choices=["serve_2k", *sorted(SHAPES_BY_NAME)])
    ap.add_argument("--area", type=float, default=50.0)
    ap.add_argument("--power", type=float, default=5.0)
    args = ap.parse_args()

    if args.shape == "serve_2k":
        # laptop-scale default: 2k-token prefill, batch 1
        shape = ShapeConfig("serve_2k", seq_len=2048, global_batch=1,
                            kind="prefill")
    else:
        shape = SHAPES_BY_NAME[args.shape]
    cons = Constraints(area_mm2=args.area, power_w=args.power,
                       energy_mj=1e9, latency_ms=1e9)  # A/P-bounded search
    print(f"shape={shape.name}  constraints: {args.area}mm^2 {args.power}W "
          f"(energy/latency unconstrained -> min-EDP inside the A/P box)")
    print(f"{'arch':24s} {'feasible':8s} {'config':34s} "
          f"{'E[mJ]':>9s} {'L[ms]':>9s}")
    for arch in list_archs():
        cfg = get_config(arch)
        wl = workload_for(cfg, shape)
        r = dxpta_search(wl, cons)
        if r.feasible:
            print(f"{arch:24s} {'yes':8s} {str(r.best_cfg):34s} "
                  f"{r.energy_j*1e3:9.1f} {r.latency_s*1e3:9.2f}")
        else:
            print(f"{arch:24s} {'NO':8s} {'-':34s} {'-':>9s} {'-':>9s}")


if __name__ == "__main__":
    main()
